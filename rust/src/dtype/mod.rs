//! Element types shared across the whole stack.
//!
//! `DType` is the single source of truth for element typing: buffers
//! ([`crate::buffer::BufferInfo`]), accessor bindings
//! ([`crate::instruction::AccessBinding`] → `executor::BindingView`) and the
//! PJRT kernel argument specs (`runtime::ArgSpec`) all reference this one
//! enum. The [`Elem`] trait maps Rust value types onto a `(DType, lanes)`
//! layout so the user-facing queue API ([`crate::driver::Queue`]) can be
//! fully typed: `Buffer<f32>`, `Buffer<[f32; 3]>`, `q.fence(buf) ->
//! Result<Vec<T>, _>`.

use std::fmt;

/// Scalar element type of a buffer lane or kernel argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I32,
    U32,
}

impl DType {
    /// Size of one scalar in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::F64 => 8,
        }
    }

    /// The manifest / display spelling ("f32", "i32", ...).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
            DType::U32 => "u32",
        }
    }

    /// Inverse of [`DType::name`], used by the artifact manifest parser.
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            "i32" => Some(DType::I32),
            "u32" => Some(DType::U32),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
    impl Sealed for i32 {}
    impl Sealed for u32 {}
    impl Sealed for [f32; 3] {}
    impl Sealed for [f64; 3] {}
}

/// A Rust value type usable as a buffer element: a scalar or a small
/// fixed-lane vector (the "double3"-style particle elements of N-body).
///
/// Sealed: the set of element types is closed so every layout has a
/// `DType` the scheduler and PJRT marshalling understand.
pub trait Elem: sealed::Sealed + Copy + Default + Send + Sync + 'static {
    /// Scalar type of each lane.
    const DTYPE: DType;
    /// Number of scalar lanes per element (1 for scalars).
    const LANES: usize;

    /// Append this element's native-endian bytes to `out`.
    fn write_ne(self, out: &mut Vec<u8>);
    /// Decode one element from exactly [`elem_size::<Self>()`] bytes.
    fn read_ne(bytes: &[u8]) -> Self;
}

/// Bytes per element of `T` (`DType` scalar size × lanes).
pub fn elem_size<T: Elem>() -> usize {
    T::DTYPE.size() * T::LANES
}

macro_rules! scalar_elem {
    ($t:ty, $d:expr) => {
        impl Elem for $t {
            const DTYPE: DType = $d;
            const LANES: usize = 1;

            fn write_ne(self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_ne_bytes());
            }

            fn read_ne(bytes: &[u8]) -> Self {
                <$t>::from_ne_bytes(bytes.try_into().expect("elem byte width"))
            }
        }
    };
}

scalar_elem!(f32, DType::F32);
scalar_elem!(f64, DType::F64);
scalar_elem!(i32, DType::I32);
scalar_elem!(u32, DType::U32);

macro_rules! vec3_elem {
    ($t:ty, $d:expr) => {
        impl Elem for [$t; 3] {
            const DTYPE: DType = $d;
            const LANES: usize = 3;

            fn write_ne(self, out: &mut Vec<u8>) {
                for lane in self {
                    out.extend_from_slice(&lane.to_ne_bytes());
                }
            }

            fn read_ne(bytes: &[u8]) -> Self {
                let w = $d.size();
                let mut v = [<$t>::default(); 3];
                for (i, lane) in v.iter_mut().enumerate() {
                    *lane = <$t>::from_ne_bytes(
                        bytes[i * w..(i + 1) * w].try_into().expect("lane byte width"),
                    );
                }
                v
            }
        }
    };
}

vec3_elem!(f32, DType::F32);
vec3_elem!(f64, DType::F64);

/// Encode a slice of typed elements as dense native-endian bytes.
pub fn to_bytes<T: Elem>(values: &[T]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * elem_size::<T>());
    for v in values {
        v.write_ne(&mut out);
    }
    out
}

/// Decode dense native-endian bytes into typed elements. `bytes.len()`
/// must be a multiple of the element size (callers validate and surface
/// `QueueError::ShapeMismatch` otherwise).
pub fn from_bytes<T: Elem>(bytes: &[u8]) -> Vec<T> {
    bytes.chunks_exact(elem_size::<T>()).map(T::read_ne).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::F64.size(), 8);
        assert_eq!(DType::I32.size(), 4);
        assert_eq!(DType::U32.size(), 4);
        assert_eq!(elem_size::<f32>(), 4);
        assert_eq!(elem_size::<f64>(), 8);
        assert_eq!(elem_size::<[f32; 3]>(), 12);
        assert_eq!(elem_size::<[f64; 3]>(), 24);
    }

    #[test]
    fn parse_round_trips_names() {
        for d in [DType::F32, DType::F64, DType::I32, DType::U32] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("f16"), None);
    }

    #[test]
    fn bytes_round_trip_scalars() {
        let f: Vec<f32> = vec![0.0, -1.5, 3.25];
        assert_eq!(from_bytes::<f32>(&to_bytes(&f)), f);
        let i: Vec<i32> = vec![-7, 0, 123456];
        assert_eq!(from_bytes::<i32>(&to_bytes(&i)), i);
        let d: Vec<f64> = vec![1e-12, -2.5];
        assert_eq!(from_bytes::<f64>(&to_bytes(&d)), d);
        let u: Vec<u32> = vec![0, u32::MAX];
        assert_eq!(from_bytes::<u32>(&to_bytes(&u)), u);
    }

    #[test]
    fn bytes_round_trip_vec3() {
        let v: Vec<[f32; 3]> = vec![[1.0, 2.0, 3.0], [-0.5, 0.0, 9.0]];
        let b = to_bytes(&v);
        assert_eq!(b.len(), 24);
        assert_eq!(from_bytes::<[f32; 3]>(&b), v);
    }

    #[test]
    fn layout_matches_flat_scalars() {
        // [f32; 3] elements must serialize exactly like 3 interleaved f32s
        // (the apps convert flat golden-model state to typed elements).
        let flat: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let elems: Vec<[f32; 3]> = vec![[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]];
        assert_eq!(to_bytes(&flat), to_bytes(&elems));
    }
}
