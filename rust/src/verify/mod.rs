//! Static instruction-graph verification: race, lifetime, coherence and
//! communication analysis over the compiled IDAG.
//!
//! The paper's central claim — that the instruction graph "preserves full
//! concurrency between memory management, data transfers, MPI peer-to-peer
//! communication and kernel invocation" — is only safe if every pair of
//! conflicting accesses is provably ordered by a dependency path. The
//! generators in [`crate::command`] and [`crate::instruction`] emit
//! dependencies *by construction*; this module checks the result
//! *by analysis*, without executing anything:
//!
//! 1. **Race-freedom** — every instruction pair touching overlapping
//!    `(AllocationId, GridBox)` regions with at least one write is ordered
//!    by a dependency path (reachability over the topological stream order,
//!    with per-allocation [`RegionMap`] interval indexes so the check
//!    scales past toy graphs).
//! 2. **Allocation lifetime** — every access hits a live allocation whose
//!    `alloc` happens-before the access and whose `free` happens-after
//!    every recorded use.
//! 3. **Coherence / initialization** — every read's bytes were produced by
//!    an ordered writer (kernel, receive, copy, or the user-init epoch),
//!    so no instruction reads uninitialized memory.
//! 4. **Communication matching** — every `send` has an eagerly-announced
//!    pilot with identical geometry, message ids are collision-free and
//!    stay inside the job's id namespace; [`verify_cluster`] additionally
//!    matches sends against the receives implied by the peers'
//!    deterministically-replicated CDAG state, and cross-checks collective
//!    ring geometry across nodes.
//! 5. **Structural invariants** — no dangling or forward (cyclic)
//!    dependency edges, no duplicate instruction ids, and every
//!    horizon/epoch dominates the entire graph built before it (the §3.5
//!    pruning soundness condition).
//!
//! ## How reachability scales
//!
//! Instruction ids are assigned monotonically and every dependency edge
//! points backwards, so arrival order *is* a topological order. Each
//! instruction gets a compressed ancestor set ([`crate::dag::reach::Reach`],
//! shared with the [`crate::analyze`] performance analyzer): a `floor`
//! (every earlier instruction below it is an ancestor) plus a bitset
//! covering `[floor, self)`. Horizons and epochs depend on the entire
//! execution front, which makes them dominators: once verified complete,
//! their ancestor set collapses to `floor == self` — so bitsets only ever
//! span the instructions between two horizons, not the whole history,
//! mirroring the §3.5 memory argument of the scheduler itself.
//!
//! ## Incremental verification (state compaction)
//!
//! The reachability bitsets are bounded by the boundary collapse above,
//! but the per-allocation access trackers (`users`, last-writer and
//! reader-set region maps) historically grew with the whole stream, so
//! re-checking a long epoch cost work proportional to everything compiled
//! since startup. [`Verifier::incremental`] additionally *compacts* that
//! state at verified boundaries, mirroring the generator's own horizon
//! substitution (§3.5): when epoch `E` at dense index `e` passes the
//! domination check, every tracked index `< e` is substituted by `e`; when
//! horizon `H_k` passes, indexes below the *previous* boundary `H_{k-1}`
//! are substituted by it (the generator applies horizon `N` only once
//! horizon `N+1` is generated, so instructions emitted after `H_k` route
//! all pre-`H_{k-1}` dependencies through `H_{k-1}`). On
//! generator-produced streams the verdicts are identical to a
//! from-scratch pass — `rust/tests/verify_prop.rs` asserts exactly that on
//! every seed — while per-batch work stays proportional to the span since
//! the last applied boundary, not the epoch. Hand-built adversarial
//! streams should keep using the from-scratch [`verify_stream`] /
//! [`Verifier::new`], whose diagnostics always name the original
//! instruction pair.
//!
//! ## Wiring
//!
//! - `celerity run/worker/sim --verify` — each scheduler core absorbs its
//!   own output batch-by-batch; violations surface through the §4.4 error
//!   stream ([`crate::task::QueueError::Runtime`]) naming the offending
//!   instruction pair and region.
//! - Scheduler unit tests run with `verify: true` unconditionally, so
//!   every generator change is audited.
//! - `rust/tests/verify_prop.rs` fuzzes randomized workloads (≥100 seeds,
//!   collectives/direct-comm/lookahead on and off) through the full
//!   pipeline and requires zero violations.
//!
//! With `--verify` off the runtime cost is a single branch per scheduler
//! batch (`Option<Verifier>` check); the bench row `verify (rsim stream)`
//! in `micro_scheduler` prices the analysis itself.

use crate::buffer::BufferPool;
use crate::dag::reach::Reach;
use crate::grid::{GridBox, Region, RegionMap};
use crate::instruction::{user_alloc_id, InstructionKind, InstructionRef, Pilot};
use crate::util::{AllocationId, JobId, MemoryId, MessageId, NodeId, TaskId};
use std::collections::HashMap;
use std::fmt;

/// Marker bit of [`user_alloc_id`]: the reserved id space of pre-existing
/// user-memory (M0) backings, which have no `alloc`/`free` instructions and
/// whose contents the init epoch produced.
const USER_ALLOC_BIT: u64 = 1 << 62;

fn is_user_alloc(a: AllocationId) -> bool {
    a.0 & USER_ALLOC_BIT != 0
}

// ─────────────────────────────────────────────────────────────────────────
// Violations
// ─────────────────────────────────────────────────────────────────────────

/// One verification failure. Every variant names the offending instruction
/// (pair) by id and mnemonic plus the memory/allocation/box context needed
/// to localize the bug in the generator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// Two accesses to overlapping bytes, at least one a write, with no
    /// dependency path ordering them.
    Race {
        earlier: u64,
        earlier_what: &'static str,
        later: u64,
        later_what: &'static str,
        memory: MemoryId,
        alloc: AllocationId,
        overlap: GridBox,
        write_write: bool,
    },
    /// An access to an allocation that was already freed (or is unordered
    /// with its free).
    UseAfterFree {
        free: u64,
        access: u64,
        access_what: &'static str,
        memory: MemoryId,
        alloc: AllocationId,
        ordered: bool,
    },
    /// A free that is not ordered after one of the allocation's users.
    FreeBeforeUse {
        free: u64,
        user: u64,
        user_what: &'static str,
        memory: MemoryId,
        alloc: AllocationId,
    },
    /// An access to an allocation id no `alloc` instruction defined.
    MissingAlloc { access: u64, access_what: &'static str, alloc: AllocationId },
    /// An access that is not ordered after the allocation that backs it.
    AccessBeforeAlloc { access: u64, access_what: &'static str, alloc: AllocationId },
    /// An access outside the box its backing allocation covers.
    OutOfBounds {
        access: u64,
        access_what: &'static str,
        alloc: AllocationId,
        covers: GridBox,
        touched: GridBox,
    },
    /// A read of bytes no ordered producer ever wrote.
    UninitRead {
        access: u64,
        access_what: &'static str,
        memory: MemoryId,
        alloc: AllocationId,
        uninit: GridBox,
    },
    /// A dependency edge to an instruction id never seen in the stream.
    DanglingDep { instr: u64, what: &'static str, dep: u64 },
    /// A dependency edge pointing forward in id order (would be a cycle).
    ForwardDep { instr: u64, what: &'static str, dep: u64 },
    /// Two instructions carrying the same id.
    DuplicateId { id: u64, what: &'static str },
    /// Two `alloc` instructions defining the same allocation id.
    DuplicateAlloc { instr: u64, alloc: AllocationId },
    /// A horizon/epoch that does not dominate every older instruction —
    /// §3.5 pruning would be unsound.
    UnorderedBoundary { boundary: u64, what: &'static str, missed: u64, missed_what: &'static str },
    /// A send without a matching eagerly-announced pilot, or a pilot whose
    /// geometry disagrees with its send.
    PilotMismatch { send: u64, msg: MessageId, detail: String },
    /// A message id used twice, or one outside the job's id namespace.
    MessageCollision { instr: u64, msg: MessageId, detail: String },
    /// Cross-node communication that does not line up (orphan receive,
    /// orphan send, or inconsistent collective geometry).
    CommMismatch { node: NodeId, instr: u64, detail: String },
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::Race {
                earlier,
                earlier_what,
                later,
                later_what,
                memory,
                alloc,
                overlap,
                write_write,
            } => write!(
                f,
                "verify: race between I{earlier} ({earlier_what}) and I{later} ({later_what}): \
                 {} of {overlap} in {alloc} on {memory} with no dependency path",
                if *write_write { "conflicting writes" } else { "unordered read/write" }
            ),
            Violation::UseAfterFree { free, access, access_what, memory, alloc, ordered } => {
                write!(
                    f,
                    "verify: I{access} ({access_what}) touches {alloc} on {memory} {} \
                     its free I{free}",
                    if *ordered { "after" } else { "unordered with" }
                )
            }
            Violation::FreeBeforeUse { free, user, user_what, memory, alloc } => write!(
                f,
                "verify: free I{free} of {alloc} on {memory} is not ordered after its \
                 user I{user} ({user_what})"
            ),
            Violation::MissingAlloc { access, access_what, alloc } => write!(
                f,
                "verify: I{access} ({access_what}) references {alloc} which no alloc \
                 instruction defined"
            ),
            Violation::AccessBeforeAlloc { access, access_what, alloc } => write!(
                f,
                "verify: I{access} ({access_what}) is not ordered after the alloc of {alloc}"
            ),
            Violation::OutOfBounds { access, access_what, alloc, covers, touched } => write!(
                f,
                "verify: I{access} ({access_what}) touches {touched} outside {alloc} \
                 which covers {covers}"
            ),
            Violation::UninitRead { access, access_what, memory, alloc, uninit } => write!(
                f,
                "verify: I{access} ({access_what}) reads {uninit} of {alloc} on {memory} \
                 which no ordered producer ever wrote"
            ),
            Violation::DanglingDep { instr, what, dep } => write!(
                f,
                "verify: I{instr} ({what}) depends on I{dep} which never appeared in the stream"
            ),
            Violation::ForwardDep { instr, what, dep } => write!(
                f,
                "verify: I{instr} ({what}) depends forward on I{dep} (cycle in id order)"
            ),
            Violation::DuplicateId { id, what } => {
                write!(f, "verify: instruction id I{id} ({what}) emitted twice")
            }
            Violation::DuplicateAlloc { instr, alloc } => {
                write!(f, "verify: I{instr} re-allocates live allocation {alloc}")
            }
            Violation::UnorderedBoundary { boundary, what, missed, missed_what } => write!(
                f,
                "verify: {what} I{boundary} does not dominate I{missed} ({missed_what}); \
                 §3.5 pruning would be unsound"
            ),
            Violation::PilotMismatch { send, msg, detail } => {
                write!(f, "verify: send I{send} ({msg}): {detail}")
            }
            Violation::MessageCollision { instr, msg, detail } => {
                write!(f, "verify: I{instr} ({msg}): {detail}")
            }
            Violation::CommMismatch { node, instr, detail } => {
                write!(f, "verify: {node} I{instr}: {detail}")
            }
        }
    }
}

/// Render a violation attributed to its owning job. Job 0 — the
/// single-tenant default — keeps the bare `verify:` prefix every existing
/// consumer greps for; multi-tenant jobs are tagged so a shared §4.4 error
/// stream no longer requires decoding the instruction-id namespace by
/// hand.
pub fn attribute(job: JobId, v: &Violation) -> String {
    let text = v.to_string();
    if job == JobId(0) {
        text
    } else {
        text.replacen("verify:", &format!("verify[{job}]:"), 1)
    }
}

// ─────────────────────────────────────────────────────────────────────────
// Per-allocation access tracking
// ─────────────────────────────────────────────────────────────────────────

/// Interval-indexed access history of one allocation. Box coordinates are
/// buffer coordinates (allocations cover buffer-space boxes), so the
/// extents come straight from the buffer registry.
#[derive(Debug)]
struct AllocState {
    memory: MemoryId,
    covers: GridBox,
    /// Dense index of the defining `alloc` instruction; `None` for the
    /// pre-existing user (M0) backing.
    alloc_idx: Option<usize>,
    /// Dense index of the `free`, once seen.
    freed: Option<usize>,
    /// Every access recorded so far (for the free-ordering check).
    users: Vec<usize>,
    /// Last writer per box; `None` = never written.
    writers: RegionMap<Option<usize>>,
    /// Readers since the last write per box.
    readers: RegionMap<Vec<usize>>,
}

/// One byte-level access an instruction performs.
struct Access {
    alloc: AllocationId,
    region: Region,
    write: bool,
}

impl Access {
    fn read(alloc: AllocationId, region: Region) -> Access {
        Access { alloc, region, write: false }
    }
    fn write(alloc: AllocationId, region: Region) -> Access {
        Access { alloc, region, write: true }
    }
}

// ─────────────────────────────────────────────────────────────────────────
// The verifier
// ─────────────────────────────────────────────────────────────────────────

/// Incremental single-node, single-job IDAG verifier. Feed it every batch
/// the scheduler emits (instructions *and* pilots, in stream order); drain
/// violations with [`Verifier::take_violations`].
///
/// Two modes:
/// - [`Verifier::new`] — from-scratch reference: tracking state is never
///   pruned, so horizon-substituted dependencies are checked against the
///   *original* producers and every diagnostic names the true pair.
/// - [`Verifier::incremental`] — compacts the per-allocation trackers at
///   verified boundaries (see the module docs), keeping per-batch work
///   proportional to the span since the last applied boundary. This is
///   what the scheduler's in-core `--verify` path runs, so verification
///   stays cheap enough to leave on under lookahead.
#[derive(Debug)]
pub struct Verifier {
    job: JobId,
    node: NodeId,
    buffers: BufferPool,
    /// InstructionId → dense stream index.
    index: HashMap<u64, usize>,
    /// Per dense index: (raw id, mnemonic).
    instrs: Vec<(u64, &'static str)>,
    reach: Vec<Reach>,
    allocs: HashMap<AllocationId, AllocState>,
    /// Pilots announced so far, by message id.
    pilots: HashMap<MessageId, Pilot>,
    /// Message ids consumed by sends/collectives (dense index of consumer).
    msgs_used: HashMap<MessageId, usize>,
    violations: Vec<Violation>,
    /// Compact tracker state at verified boundaries (incremental mode).
    compact: bool,
    /// Dense index of the last *verified* boundary (incremental mode); the
    /// two-boundary lag: horizon `k` compacts state below horizon `k−1`,
    /// mirroring "horizon N is applied when horizon N+1 is generated".
    last_boundary: Option<usize>,
    /// Everything below this dense index has been substituted away.
    compacted_below: usize,
    /// Instructions absorbed (monotonic; survives `take_violations`).
    pub instructions_verified: u64,
}

impl Verifier {
    pub fn new(job: JobId, node: NodeId, buffers: BufferPool) -> Self {
        Verifier {
            job,
            node,
            buffers,
            index: HashMap::new(),
            instrs: Vec::new(),
            reach: Vec::new(),
            allocs: HashMap::new(),
            pilots: HashMap::new(),
            msgs_used: HashMap::new(),
            violations: Vec::new(),
            compact: false,
            last_boundary: None,
            compacted_below: 0,
            instructions_verified: 0,
        }
    }

    /// A verifier that compacts its tracking state at verified boundaries
    /// (see the module docs). Verdict-identical to [`Verifier::new`] on
    /// generator-produced streams; per-batch work is bounded by the span
    /// since the last applied boundary instead of the whole epoch.
    pub fn incremental(job: JobId, node: NodeId, buffers: BufferPool) -> Self {
        Verifier { compact: true, ..Verifier::new(job, node, buffers) }
    }

    /// Whether this verifier compacts state at boundaries.
    pub fn is_incremental(&self) -> bool {
        self.compact
    }

    /// Dense indexes already substituted by a boundary (diagnostics: the
    /// incremental bench reports how much of the stream stays live).
    pub fn compacted_below(&self) -> usize {
        self.compacted_below
    }

    /// Register newly created buffers (mirrors
    /// [`crate::scheduler::Scheduler::notify_buffers`]).
    pub fn notify_buffers(&mut self, pool: BufferPool) {
        self.buffers = pool;
    }

    /// Drain the violations found so far.
    pub fn take_violations(&mut self) -> Vec<Violation> {
        std::mem::take(&mut self.violations)
    }

    /// Absorb one scheduler output batch. Pilots are registered first: the
    /// generator announces them eagerly in the same compile step as their
    /// send, so within a batch the pilot always precedes its consumer.
    pub fn absorb_batch(&mut self, instructions: &[InstructionRef], pilots: &[Pilot]) {
        for p in pilots {
            if let Some(prev) = self.pilots.insert(p.msg, p.clone()) {
                self.violations.push(Violation::MessageCollision {
                    instr: 0,
                    msg: p.msg,
                    detail: format!(
                        "pilot for {} {} announced twice (first {})",
                        p.buffer, p.send_box, prev.send_box
                    ),
                });
            }
        }
        for i in instructions {
            self.absorb_instruction(i);
        }
    }

    fn absorb_instruction(&mut self, instr: &InstructionRef) {
        self.instructions_verified += 1;
        let what = instr.kind.mnemonic();
        let raw = instr.id.0;
        let cur = self.instrs.len();
        if self.index.insert(raw, cur).is_some() {
            self.violations.push(Violation::DuplicateId { id: raw, what });
            // Keep going: later references resolve to this newest copy.
        }
        self.instrs.push((raw, what));

        // Structural checks + dense dep resolution.
        let mut dep_idxs: Vec<usize> = Vec::with_capacity(instr.deps.len());
        for (dep, _) in &instr.deps {
            match self.index.get(&dep.0) {
                Some(&d) if d < cur => dep_idxs.push(d),
                Some(_) => {
                    self.violations.push(Violation::ForwardDep { instr: raw, what, dep: dep.0 })
                }
                None if dep.0 >= raw => {
                    self.violations.push(Violation::ForwardDep { instr: raw, what, dep: dep.0 })
                }
                None => {
                    self.violations.push(Violation::DanglingDep { instr: raw, what, dep: dep.0 })
                }
            }
        }

        // Ancestor set: floor = max dep floor, bits = union of dep bits.
        let mut reach = Reach::from_deps(&dep_idxs, &self.reach);

        // Boundary domination + compression (§3.5): a horizon/epoch must
        // have every older instruction as an ancestor; its set then
        // collapses to `floor == self`, bounding all later bitsets.
        if matches!(instr.kind, InstructionKind::Horizon | InstructionKind::Epoch(_)) {
            match reach.first_unreached(cur) {
                None => {
                    reach = Reach::collapsed(cur);
                    if self.compact {
                        self.apply_boundary(cur, matches!(instr.kind, InstructionKind::Epoch(_)));
                    }
                }
                Some(missed) => {
                    let (mid, mwhat) = self.instrs[missed];
                    self.violations.push(Violation::UnorderedBoundary {
                        boundary: raw,
                        what,
                        missed: mid,
                        missed_what: mwhat,
                    });
                }
            }
        }
        self.reach.push(reach);

        // Kind-specific semantics.
        match &instr.kind {
            InstructionKind::Alloc { alloc, memory, buffer, covers, .. } => {
                self.define_alloc(cur, raw, *alloc, *memory, *buffer, *covers);
            }
            InstructionKind::Free { alloc, .. } => self.free_alloc(cur, raw, *alloc),
            InstructionKind::Send { send_box, src_alloc, target, msg, buffer, .. } => {
                self.check_send(cur, raw, *msg, *buffer, *send_box, *target);
                self.apply_accesses(
                    cur,
                    raw,
                    what,
                    &[Access::read(*src_alloc, Region::from(*send_box))],
                );
            }
            InstructionKind::Receive { region, dst_alloc, .. }
            | InstructionKind::SplitReceive { region, dst_alloc, .. } => {
                self.apply_accesses(cur, raw, what, &[Access::write(*dst_alloc, region.clone())]);
            }
            // The await is an ordering proxy: the bytes were written by its
            // split receive, which it depends on.
            InstructionKind::AwaitReceive { .. } => {}
            InstructionKind::Collective {
                region, slices, dst_alloc, msgs, buffer, transfer, ..
            } => {
                self.check_collective(cur, raw, *buffer, *transfer, slices, msgs);
                let own = slices
                    .get(self.node.0 as usize)
                    .map(|s| Region::from(*s))
                    .unwrap_or_else(Region::empty);
                let inbound = region.difference(&own);
                let mut acc = Vec::new();
                if !own.is_empty() {
                    acc.push(Access::read(*dst_alloc, own));
                }
                if !inbound.is_empty() {
                    acc.push(Access::write(*dst_alloc, inbound));
                }
                self.apply_accesses(cur, raw, what, &acc);
            }
            InstructionKind::Copy { copy_box, src_alloc, dst_alloc, .. } => {
                self.apply_accesses(
                    cur,
                    raw,
                    what,
                    &[
                        Access::read(*src_alloc, Region::from(*copy_box)),
                        Access::write(*dst_alloc, Region::from(*copy_box)),
                    ],
                );
            }
            InstructionKind::DeviceKernel { bindings, .. }
            | InstructionKind::HostTask { bindings, .. } => {
                let mut acc = Vec::new();
                for b in bindings {
                    if b.region.is_empty() {
                        continue;
                    }
                    if b.mode.is_consumer() {
                        acc.push(Access::read(b.alloc, b.region.clone()));
                    }
                    if b.mode.is_producer() {
                        acc.push(Access::write(b.alloc, b.region.clone()));
                    }
                }
                self.apply_accesses(cur, raw, what, &acc);
            }
            InstructionKind::Horizon | InstructionKind::Epoch(_) => {}
        }
    }

    /// A boundary at dense index `cur` passed the domination check
    /// (incremental mode). Epochs substitute immediately (`bound = cur`);
    /// horizons substitute below the *previous* verified boundary — the
    /// generator applies horizon `N` only when horizon `N+1` is generated,
    /// so only pre-`N` trackers are guaranteed to have been rerouted.
    fn apply_boundary(&mut self, cur: usize, is_epoch: bool) {
        let bound = if is_epoch { Some(cur) } else { self.last_boundary };
        self.last_boundary = Some(cur);
        if let Some(b) = bound {
            if b > self.compacted_below {
                self.compact_state(b);
                self.compacted_below = b;
            }
        }
    }

    /// Substitute every tracked dense index `< bound` with `bound` — the
    /// verifier-side mirror of the generator's horizon substitution. Any
    /// later access to a region whose tracked writer/reader predates the
    /// applied boundary has its dependency routed through that boundary by
    /// the generator, so `reach.contains(bound)` decides exactly as
    /// `reach.contains(original)` would. Diagnostics on *violating*
    /// streams may name the boundary instead of the original instruction;
    /// the from-scratch mode exists for exact attribution.
    fn compact_state(&mut self, bound: usize) {
        for st in self.allocs.values_mut() {
            if st.users.first().is_some_and(|&u| u < bound) {
                // `users` is non-decreasing (indexes are pushed in stream
                // order), so substitution keeps it sorted and `dedup`
                // removes the collapsed prefix.
                for u in st.users.iter_mut() {
                    if *u < bound {
                        *u = bound;
                    }
                }
                st.users.dedup();
            }
            let everything = Region::from(st.writers.extent());
            st.writers.apply_to_region(&everything, |w| match w {
                Some(i) if *i < bound => Some(bound),
                other => *other,
            });
            st.readers.apply_to_region(&everything, |rs| {
                if rs.iter().any(|&r| r < bound) {
                    let mut out = vec![bound];
                    out.extend(rs.iter().copied().filter(|&r| r > bound));
                    out
                } else {
                    rs.clone()
                }
            });
        }
    }

    fn define_alloc(
        &mut self,
        cur: usize,
        raw: u64,
        alloc: AllocationId,
        memory: MemoryId,
        buffer: Option<crate::util::BufferId>,
        covers: GridBox,
    ) {
        if self.allocs.get(&alloc).is_some_and(|a| a.freed.is_none()) {
            self.violations.push(Violation::DuplicateAlloc { instr: raw, alloc });
            return;
        }
        let range = buffer
            .and_then(|b| self.buffers.try_get(b).map(|info| info.range))
            .unwrap_or_else(|| covers.range());
        self.allocs.insert(
            alloc,
            AllocState {
                memory,
                covers,
                alloc_idx: Some(cur),
                freed: None,
                users: Vec::new(),
                writers: RegionMap::new(range, None),
                readers: RegionMap::new(range, Vec::new()),
            },
        );
    }

    fn free_alloc(&mut self, cur: usize, raw: u64, alloc: AllocationId) {
        let Some(st) = self.allocs.get_mut(&alloc) else {
            self.violations.push(Violation::MissingAlloc {
                access: raw,
                access_what: "free",
                alloc,
            });
            return;
        };
        if let Some(prev) = st.freed {
            let (fid, _) = self.instrs[prev];
            self.violations.push(Violation::UseAfterFree {
                free: fid,
                access: raw,
                access_what: "free",
                memory: st.memory,
                alloc,
                ordered: self.reach[cur].contains(prev),
            });
            return;
        }
        st.freed = Some(cur);
        let users = st.users.clone();
        let memory = st.memory;
        for u in users {
            if u != cur && !self.reach[cur].contains(u) {
                let (uid, uwhat) = self.instrs[u];
                self.violations.push(Violation::FreeBeforeUse {
                    free: raw,
                    user: uid,
                    user_what: uwhat,
                    memory,
                    alloc,
                });
            }
        }
    }

    /// Check and record the byte accesses of one instruction. Reads are
    /// processed before writes so a read-write instruction does not race
    /// with itself.
    fn apply_accesses(&mut self, cur: usize, raw: u64, what: &'static str, accesses: &[Access]) {
        for a in accesses.iter().filter(|a| !a.write) {
            self.check_access(cur, raw, what, a);
        }
        for a in accesses.iter().filter(|a| a.write) {
            self.check_access(cur, raw, what, a);
        }
        // Record after checking so overlapping accesses of the same
        // instruction (read-write bindings) do not self-conflict.
        for a in accesses {
            let Some(st) = self.allocs.get_mut(&a.alloc) else { continue };
            st.users.push(cur);
            if a.write {
                st.writers.update_region(&a.region, Some(cur));
                st.readers.update_region(&a.region, Vec::new());
            } else {
                st.readers.apply_to_region(&a.region, |rs| {
                    let mut rs = rs.clone();
                    rs.push(cur);
                    rs
                });
            }
        }
    }

    fn check_access(&mut self, cur: usize, raw: u64, what: &'static str, a: &Access) {
        let user_mem = is_user_alloc(a.alloc);
        if user_mem && !self.allocs.contains_key(&a.alloc) {
            // Pre-existing user (M0) backing: synthesize an always-live
            // allocation whose contents the init epoch produced. Reads are
            // exempt from the uninit check — the executor materializes the
            // user bytes before the first instruction references them.
            let buffer = crate::util::BufferId(a.alloc.0 & !USER_ALLOC_BIT);
            let range = match self.buffers.try_get(buffer) {
                Some(info) => info.range,
                None => a.region.bounding_box().range(),
            };
            self.allocs.insert(
                a.alloc,
                AllocState {
                    memory: MemoryId::USER,
                    covers: GridBox::full(range),
                    alloc_idx: None,
                    freed: None,
                    users: Vec::new(),
                    writers: RegionMap::new(range, None),
                    readers: RegionMap::new(range, Vec::new()),
                },
            );
        }
        let Some(st) = self.allocs.get(&a.alloc) else {
            self.violations.push(Violation::MissingAlloc {
                access: raw,
                access_what: what,
                alloc: a.alloc,
            });
            return;
        };
        let reach = &self.reach[cur];
        let mut found: Vec<Violation> = Vec::new();

        // Lifetime: alloc happens-before, free happens-after.
        if let Some(ai) = st.alloc_idx {
            if !reach.contains(ai) {
                found.push(Violation::AccessBeforeAlloc {
                    access: raw,
                    access_what: what,
                    alloc: a.alloc,
                });
            }
        }
        if let Some(fi) = st.freed {
            let (fid, _) = self.instrs[fi];
            found.push(Violation::UseAfterFree {
                free: fid,
                access: raw,
                access_what: what,
                memory: st.memory,
                alloc: a.alloc,
                ordered: reach.contains(fi),
            });
        }

        for bx in a.region.boxes() {
            if !st.covers.contains(bx) {
                found.push(Violation::OutOfBounds {
                    access: raw,
                    access_what: what,
                    alloc: a.alloc,
                    covers: st.covers,
                    touched: *bx,
                });
            }
        }

        // Races + initialization, per interval fragment.
        st.writers.for_each_in_region(&a.region, |bx, w| match w {
            Some(&wi) if wi != cur => {
                if !reach.contains(wi) {
                    let (wid, wwhat) = self.instrs[wi];
                    found.push(Violation::Race {
                        earlier: wid,
                        earlier_what: wwhat,
                        later: raw,
                        later_what: what,
                        memory: st.memory,
                        alloc: a.alloc,
                        overlap: bx,
                        write_write: a.write,
                    });
                }
            }
            Some(_) => {}
            None => {
                if !a.write && !user_mem {
                    found.push(Violation::UninitRead {
                        access: raw,
                        access_what: what,
                        memory: st.memory,
                        alloc: a.alloc,
                        uninit: bx,
                    });
                }
            }
        });
        if a.write {
            st.readers.for_each_in_region(&a.region, |bx, rs| {
                for &ri in rs {
                    if ri != cur && !reach.contains(ri) {
                        let (rid, rwhat) = self.instrs[ri];
                        found.push(Violation::Race {
                            earlier: rid,
                            earlier_what: rwhat,
                            later: raw,
                            later_what: what,
                            memory: st.memory,
                            alloc: a.alloc,
                            overlap: bx,
                            write_write: false,
                        });
                    }
                }
            });
        }
        self.violations.extend(found);
    }

    fn check_send(
        &mut self,
        cur: usize,
        raw: u64,
        msg: MessageId,
        buffer: crate::util::BufferId,
        send_box: GridBox,
        target: NodeId,
    ) {
        self.check_msg(cur, raw, msg);
        if target == self.node {
            self.violations.push(Violation::CommMismatch {
                node: self.node,
                instr: raw,
                detail: "send targets its own node".into(),
            });
        }
        match self.pilots.get(&msg) {
            None => self.violations.push(Violation::PilotMismatch {
                send: raw,
                msg,
                detail: "no pilot was announced for this message".into(),
            }),
            Some(p) => {
                if p.send_box != send_box || p.to != target || p.buffer != buffer {
                    self.violations.push(Violation::PilotMismatch {
                        send: raw,
                        msg,
                        detail: format!(
                            "pilot geometry {} {} →{} disagrees with send {} {} →{}",
                            p.buffer, p.send_box, p.to, buffer, send_box, target
                        ),
                    });
                }
            }
        }
    }

    fn check_collective(
        &mut self,
        cur: usize,
        raw: u64,
        buffer: crate::util::BufferId,
        transfer: TaskId,
        slices: &std::sync::Arc<Vec<GridBox>>,
        msgs: &[MessageId],
    ) {
        let n = slices.len();
        if msgs.len() + 1 != n {
            self.violations.push(Violation::CommMismatch {
                node: self.node,
                instr: raw,
                detail: format!("collective over {n} slices carries {} ring messages", msgs.len()),
            });
        }
        let me = self.node.0 as usize;
        let succ = NodeId(((me + 1) % n.max(1)) as u64);
        for (r, &msg) in msgs.iter().enumerate() {
            self.check_msg(cur, raw, msg);
            // Round r forwards slice (me − r) mod n to the successor; a
            // pilot must have been announced for every non-empty round.
            let send_box = slices[(me + n - r) % n];
            if send_box.is_empty() {
                continue;
            }
            match self.pilots.get(&msg) {
                None => self.violations.push(Violation::PilotMismatch {
                    send: raw,
                    msg,
                    detail: format!("no pilot announced for collective ring round {r}"),
                }),
                Some(p) => {
                    if p.send_box != send_box || p.to != succ || p.buffer != buffer
                        || p.transfer != transfer
                    {
                        self.violations.push(Violation::PilotMismatch {
                            send: raw,
                            msg,
                            detail: format!(
                                "ring round {r} pilot {} {} →{} disagrees with expected \
                                 {} {} →{}",
                                p.buffer, p.send_box, p.to, buffer, send_box, succ
                            ),
                        });
                    }
                }
            }
        }
    }

    fn check_msg(&mut self, cur: usize, raw: u64, msg: MessageId) {
        if JobId::of(msg.0) != self.job {
            self.violations.push(Violation::MessageCollision {
                instr: raw,
                msg,
                detail: format!(
                    "message id escapes the {} namespace (tagged {})",
                    self.job,
                    JobId::of(msg.0)
                ),
            });
        }
        if let Some(&prev) = self.msgs_used.get(&msg) {
            let (pid, _) = self.instrs[prev];
            self.violations.push(Violation::MessageCollision {
                instr: raw,
                msg,
                detail: format!("message id already used by I{pid}"),
            });
        } else {
            self.msgs_used.insert(msg, cur);
        }
    }
}

/// One-shot verification of a complete single-node stream.
pub fn verify_stream(
    job: JobId,
    node: NodeId,
    buffers: BufferPool,
    instructions: &[InstructionRef],
    pilots: &[Pilot],
) -> Vec<Violation> {
    let mut v = Verifier::new(job, node, buffers);
    v.absorb_batch(instructions, pilots);
    v.take_violations()
}

// ─────────────────────────────────────────────────────────────────────────
// Cluster-level communication matching
// ─────────────────────────────────────────────────────────────────────────

/// One node's complete compiled output, input to [`verify_cluster`].
#[derive(Debug, Clone)]
pub struct NodeStream {
    pub node: NodeId,
    pub instructions: Vec<InstructionRef>,
    pub pilots: Vec<Pilot>,
}

/// Cross-node checks over all nodes of one job: every send lands inside a
/// peer receive for the same `(buffer, transfer)`, every receive is fully
/// covered by peer sends, collective geometry is replicated identically,
/// and message ids are unique per sender link. Complements the per-node
/// [`Verifier`], which cannot see the peers' graphs.
pub fn verify_cluster(streams: &[NodeStream]) -> Vec<Violation> {
    use crate::util::BufferId;
    let mut violations = Vec::new();

    type Key = (NodeId, BufferId, TaskId); // receiving node, buffer, transfer
    let mut sends: HashMap<Key, Vec<(NodeId, u64, GridBox)>> = HashMap::new();
    let mut recvs: HashMap<Key, Vec<(u64, Region)>> = HashMap::new();
    // (buffer, transfer) → per-node collective geometry.
    type CollKey = (BufferId, TaskId);
    let mut colls: HashMap<CollKey, Vec<(NodeId, u64, Region, Vec<GridBox>, &'static str)>> =
        HashMap::new();

    for s in streams {
        let mut seen_msgs: HashMap<MessageId, u64> = HashMap::new();
        // Sends are grouped by the transfer (task) id their pilot announced,
        // so they land in the same bucket as the peer's receives for that
        // transfer.
        let pilot_transfer: HashMap<MessageId, TaskId> =
            s.pilots.iter().map(|p| (p.msg, p.transfer)).collect();
        for i in &s.instructions {
            match &i.kind {
                InstructionKind::Send { buffer, send_box, target, msg, .. } => {
                    if let Some(prev) = seen_msgs.insert(*msg, i.id.0) {
                        violations.push(Violation::MessageCollision {
                            instr: i.id.0,
                            msg: *msg,
                            detail: format!("message id already used by I{prev} on {}", s.node),
                        });
                    }
                    let transfer = pilot_transfer
                        .get(msg)
                        .copied()
                        .or_else(|| i.task.as_ref().map(|t| t.id))
                        .unwrap_or(TaskId(0));
                    sends.entry((*target, *buffer, transfer)).or_default().push((
                        s.node,
                        i.id.0,
                        *send_box,
                    ));
                }
                InstructionKind::Receive { buffer, region, transfer, .. }
                | InstructionKind::SplitReceive { buffer, region, transfer, .. } => {
                    recvs
                        .entry((s.node, *buffer, *transfer))
                        .or_default()
                        .push((i.id.0, region.clone()));
                }
                InstructionKind::Collective { buffer, region, slices, transfer, msgs, kind } => {
                    for m in msgs.iter() {
                        if let Some(prev) = seen_msgs.insert(*m, i.id.0) {
                            violations.push(Violation::MessageCollision {
                                instr: i.id.0,
                                msg: *m,
                                detail: format!(
                                    "message id already used by I{prev} on {}",
                                    s.node
                                ),
                            });
                        }
                    }
                    colls.entry((*buffer, *transfer)).or_default().push((
                        s.node,
                        i.id.0,
                        region.clone(),
                        slices.as_ref().clone(),
                        kind.name(),
                    ));
                }
                _ => {}
            }
        }
    }

    // p2p: every receive fully covered by sends targeting it; every send
    // inside some receive region of the target.
    for ((node, buffer, transfer), rs) in &recvs {
        let sent: Region = sends
            .get(&(*node, *buffer, *transfer))
            .map(|v| Region::from_boxes(v.iter().map(|(_, _, b)| *b)))
            .unwrap_or_else(Region::empty);
        for (id, region) in rs {
            let uncovered = region.difference(&sent);
            if !uncovered.is_empty() {
                violations.push(Violation::CommMismatch {
                    node: *node,
                    instr: *id,
                    detail: format!(
                        "receive of {buffer} {region} ({transfer}) is not covered by any \
                         peer send: {uncovered} arrives from nowhere"
                    ),
                });
            }
        }
    }
    for ((target, buffer, transfer), ss) in &sends {
        let expected: Region = recvs
            .get(&(*target, *buffer, *transfer))
            .map(|v| v.iter().fold(Region::empty(), |acc, (_, r)| acc.union(r)))
            .unwrap_or_else(Region::empty);
        for (from, id, send_box) in ss {
            let stray = Region::from(*send_box).difference(&expected);
            if !stray.is_empty() {
                violations.push(Violation::CommMismatch {
                    node: *from,
                    instr: *id,
                    detail: format!(
                        "send of {buffer} {send_box} ({transfer}) to {target} has no \
                         matching receive for {stray}"
                    ),
                });
            }
        }
    }

    // Collectives: deterministic replication means identical geometry on
    // every node, and either all nodes lower the pattern or none do.
    for ((buffer, transfer), entries) in &colls {
        let (ref_node, ref_id, ref_region, ref_slices, ref_kind) = &entries[0];
        if entries.len() != streams.len() {
            let have: Vec<NodeId> = entries.iter().map(|e| e.0).collect();
            violations.push(Violation::CommMismatch {
                node: *ref_node,
                instr: *ref_id,
                detail: format!(
                    "collective on {buffer} ({transfer}) lowered on {} of {} nodes ({have:?}): \
                     detector verdict must replicate deterministically",
                    entries.len(),
                    streams.len()
                ),
            });
        }
        for (node, id, region, slices, kind) in &entries[1..] {
            if region != ref_region || slices != ref_slices || kind != ref_kind {
                violations.push(Violation::CommMismatch {
                    node: *node,
                    instr: *id,
                    detail: format!(
                        "collective on {buffer} ({transfer}) disagrees with {ref_node} \
                         I{ref_id}: {kind} {region} vs {ref_kind} {ref_region}"
                    ),
                });
            }
        }
        if let Some(sl) = entries.iter().find(|e| e.3.len() != streams.len()) {
            violations.push(Violation::CommMismatch {
                node: sl.0,
                instr: sl.1,
                detail: format!(
                    "collective on {buffer} carries {} slices for a {}-node cluster",
                    sl.3.len(),
                    streams.len()
                ),
            });
        }
        // Mixed lowering: a node must not also p2p-push the same transfer.
        for s in streams {
            if sends.keys().any(|(_, b, t)| b == buffer && t == transfer)
                && entries.iter().any(|e| e.0 == s.node)
            {
                let (_, id, ..) = entries[0];
                violations.push(Violation::CommMismatch {
                    node: s.node,
                    instr: id,
                    detail: format!(
                        "transfer {transfer} of {buffer} lowered both as a collective and \
                         as p2p sends"
                    ),
                });
                break;
            }
        }
    }

    violations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DepKind;
    use crate::grid::Range;
    use crate::instruction::{AccessBinding, Instruction};
    use crate::scheduler::{Scheduler, SchedulerConfig};
    use crate::task::{AccessMode, RangeMapper, TaskDecl, TaskManager};
    use crate::util::{BufferId, DeviceId, InstructionId};
    use std::sync::Arc;

    fn instr(
        id: u64,
        kind: InstructionKind,
        deps: &[(u64, DepKind)],
    ) -> InstructionRef {
        Arc::new(Instruction {
            id: InstructionId(id),
            kind,
            deps: deps.iter().map(|(d, k)| (InstructionId(*d), *k)).collect(),
            task: None,
        })
    }

    fn alloc(id: u64, a: u64, mem: MemoryId, covers: GridBox) -> InstructionRef {
        instr(
            id,
            InstructionKind::Alloc {
                alloc: AllocationId(a),
                memory: mem,
                buffer: None,
                covers,
                size_bytes: covers.area() * 8,
            },
            &[],
        )
    }

    fn kernel(
        id: u64,
        a: u64,
        mode: AccessMode,
        region: GridBox,
        deps: &[(u64, DepKind)],
    ) -> InstructionRef {
        instr(
            id,
            InstructionKind::DeviceKernel {
                device: DeviceId(0),
                chunk: region,
                bindings: vec![AccessBinding {
                    buffer: BufferId(0),
                    mode,
                    region: Region::from(region),
                    alloc: AllocationId(a),
                    alloc_box: region,
                    dtype: crate::dtype::DType::F64,
                    lanes: 1,
                }],
                work_per_item: 1.0,
                kernel: None,
            },
            deps,
        )
    }

    fn run(stream: &[InstructionRef]) -> Vec<Violation> {
        verify_stream(JobId(0), NodeId(0), BufferPool::new(), stream, &[])
    }

    // ── hand-built negative cases: exact diagnostics ─────────────────────

    #[test]
    fn unordered_write_read_is_a_race_naming_pair_and_box() {
        let bx = GridBox::d1(0, 64);
        let stream = vec![
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
            // Reader depends only on the alloc — the dataflow edge to the
            // writer was "forgotten".
            kernel(3, 7, AccessMode::Read, GridBox::d1(16, 48), &[(1, DepKind::Dataflow)]),
        ];
        let vs = run(&stream);
        assert!(
            vs.iter().any(|v| matches!(
                v,
                Violation::Race {
                    earlier: 2,
                    later: 3,
                    memory: MemoryId(2),
                    alloc: AllocationId(7),
                    overlap,
                    write_write: false,
                    ..
                } if *overlap == GridBox::d1(16, 48)
            )),
            "expected race naming I2/I3 over [16,48) on M2 A7, got {vs:?}"
        );
        let text = vs[0].to_string();
        assert!(text.contains("I2") && text.contains("I3"), "{text}");
        assert!(text.contains("A7") && text.contains("M2"), "{text}");
    }

    #[test]
    fn ordered_write_read_is_clean() {
        let bx = GridBox::d1(0, 64);
        let stream = vec![
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
            kernel(3, 7, AccessMode::Read, bx, &[(2, DepKind::Dataflow)]),
        ];
        assert_eq!(run(&stream), vec![]);
    }

    #[test]
    fn write_write_race_detected_through_transitive_path_only_when_missing() {
        let bx = GridBox::d1(0, 64);
        // w1 → r → w2 is ordered through the transitive path even though w2
        // has no direct edge to w1.
        let ordered = vec![
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
            kernel(3, 7, AccessMode::Read, bx, &[(2, DepKind::Dataflow)]),
            kernel(4, 7, AccessMode::DiscardWrite, bx, &[(3, DepKind::Anti)]),
        ];
        assert_eq!(run(&ordered), vec![]);
        // Dropping the anti edge leaves both the read and (transitively)
        // the first write unordered against w2.
        let racy = vec![
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
            kernel(3, 7, AccessMode::Read, bx, &[(2, DepKind::Dataflow)]),
            kernel(4, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
        ];
        let vs = run(&racy);
        assert!(
            vs.iter().any(|v| matches!(v, Violation::Race { earlier: 3, later: 4, .. })),
            "anti-dependency race (read vs second write) expected: {vs:?}"
        );
    }

    #[test]
    fn early_free_is_use_after_free_naming_free_and_access() {
        let bx = GridBox::d1(0, 64);
        let stream = vec![
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
            instr(
                3,
                InstructionKind::Free {
                    alloc: AllocationId(7),
                    memory: MemoryId(2),
                    size_bytes: 512,
                },
                &[(2, DepKind::Anti)],
            ),
            kernel(4, 7, AccessMode::Read, bx, &[(3, DepKind::Sync)]),
        ];
        let vs = run(&stream);
        assert!(
            vs.iter().any(|v| matches!(
                v,
                Violation::UseAfterFree {
                    free: 3,
                    access: 4,
                    alloc: AllocationId(7),
                    ordered: true,
                    ..
                }
            )),
            "expected use-after-free naming I3/I4: {vs:?}"
        );
    }

    #[test]
    fn free_unordered_with_user_is_flagged() {
        let bx = GridBox::d1(0, 64);
        let stream = vec![
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
            // Free depends on the alloc only — racing the kernel.
            instr(
                3,
                InstructionKind::Free {
                    alloc: AllocationId(7),
                    memory: MemoryId(2),
                    size_bytes: 512,
                },
                &[(1, DepKind::Anti)],
            ),
        ];
        let vs = run(&stream);
        assert!(
            vs.iter().any(|v| matches!(v, Violation::FreeBeforeUse { free: 3, user: 2, .. })),
            "{vs:?}"
        );
    }

    #[test]
    fn uninit_read_and_missing_alloc_are_flagged() {
        let bx = GridBox::d1(0, 64);
        let vs = run(&[
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::Read, bx, &[(1, DepKind::Dataflow)]),
        ]);
        assert!(
            vs.iter().any(|v| matches!(v, Violation::UninitRead { access: 2, .. })),
            "{vs:?}"
        );
        let vs = run(&[kernel(1, 9, AccessMode::Read, bx, &[])]);
        assert!(
            vs.iter().any(
                |v| matches!(v, Violation::MissingAlloc { access: 1, alloc: AllocationId(9), .. })
            ),
            "{vs:?}"
        );
    }

    #[test]
    fn structural_violations_dangling_forward_duplicate() {
        let bx = GridBox::d1(0, 8);
        let vs = run(&[
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(99, DepKind::Dataflow)]),
        ]);
        assert!(
            vs.iter().any(|v| matches!(v, Violation::DanglingDep { instr: 2, dep: 99, .. })),
            "{vs:?}"
        );
        let vs = run(&[
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(5, DepKind::Dataflow)]),
        ]);
        assert!(
            vs.iter().any(|v| matches!(v, Violation::ForwardDep { instr: 2, dep: 5, .. })),
            "{vs:?}"
        );
        let vs = run(&[
            alloc(1, 7, MemoryId(2), bx),
            alloc(1, 8, MemoryId(2), bx),
        ]);
        assert!(vs.iter().any(|v| matches!(v, Violation::DuplicateId { id: 1, .. })), "{vs:?}");
    }

    #[test]
    fn incomplete_horizon_is_unordered_boundary() {
        let bx = GridBox::d1(0, 8);
        let stream = vec![
            alloc(1, 7, MemoryId(2), bx),
            kernel(2, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
            // Horizon "forgets" the kernel: only covers the alloc.
            instr(3, InstructionKind::Horizon, &[(1, DepKind::Sync)]),
        ];
        let vs = run(&stream);
        assert!(
            vs.iter()
                .any(|v| matches!(v, Violation::UnorderedBoundary { boundary: 3, missed: 2, .. })),
            "{vs:?}"
        );
    }

    #[test]
    fn send_without_pilot_is_flagged_and_with_pilot_is_clean() {
        let bx = GridBox::d1(0, 8);
        let send = instr(
            2,
            InstructionKind::Send {
                buffer: BufferId(0),
                send_box: bx,
                target: NodeId(1),
                msg: MessageId(5),
                src_memory: MemoryId(2),
                src_alloc: AllocationId(7),
                src_box: bx,
            },
            &[(1, DepKind::Dataflow)],
        );
        let stream = vec![
            alloc(1, 7, MemoryId(2), bx),
            kernel(3, 7, AccessMode::DiscardWrite, bx, &[(1, DepKind::Dataflow)]),
        ];
        // Writer first so the send's read is initialized and ordered.
        let ordered_send = instr(
            4,
            InstructionKind::Send {
                buffer: BufferId(0),
                send_box: bx,
                target: NodeId(1),
                msg: MessageId(5),
                src_memory: MemoryId(2),
                src_alloc: AllocationId(7),
                src_box: bx,
            },
            &[(3, DepKind::Dataflow)],
        );
        let mut with_pilot = stream.clone();
        with_pilot.push(ordered_send);
        let pilot = Pilot {
            from: NodeId(0),
            to: NodeId(1),
            msg: MessageId(5),
            buffer: BufferId(0),
            send_box: bx,
            transfer: TaskId(0),
        };
        let vs = verify_stream(JobId(0), NodeId(0), BufferPool::new(), &with_pilot, &[pilot]);
        assert_eq!(vs, vec![], "pilot-matched send must be clean");

        let mut without = stream;
        without.insert(1, send);
        let vs = verify_stream(JobId(0), NodeId(0), BufferPool::new(), &without, &[]);
        assert!(
            vs.iter()
                .any(|v| matches!(v, Violation::PilotMismatch { send: 2, msg: MessageId(5), .. })),
            "{vs:?}"
        );
    }

    #[test]
    fn message_namespace_violation_is_flagged() {
        let bx = GridBox::d1(0, 8);
        let job1 = JobId(1);
        // A "job 1" verifier seeing a job-0 message id.
        let stream = vec![
            alloc(job1.base() + 1, 7, MemoryId(2), bx),
            instr(
                job1.base() + 2,
                InstructionKind::Send {
                    buffer: BufferId(0),
                    send_box: bx,
                    target: NodeId(1),
                    msg: MessageId(5), // job-0 namespace
                    src_memory: MemoryId(2),
                    src_alloc: AllocationId(7),
                    src_box: bx,
                },
                &[(job1.base() + 1, DepKind::Dataflow)],
            ),
        ];
        let vs = verify_stream(job1, NodeId(0), BufferPool::new(), &stream, &[]);
        assert!(
            vs.iter().any(|v| matches!(v, Violation::MessageCollision { msg: MessageId(5), .. })),
            "{vs:?}"
        );
    }

    // ── orphan receive at cluster level ──────────────────────────────────

    #[test]
    fn orphan_receive_is_comm_mismatch() {
        let bx = GridBox::d1(0, 8);
        let recv = instr(
            2,
            InstructionKind::Receive {
                buffer: BufferId(0),
                region: Region::from(bx),
                dst_memory: MemoryId::HOST,
                dst_alloc: AllocationId(7),
                dst_box: bx,
                transfer: TaskId(3),
            },
            &[(1, DepKind::Dataflow)],
        );
        let streams = vec![
            NodeStream { node: NodeId(0), instructions: vec![], pilots: vec![] },
            NodeStream {
                node: NodeId(1),
                instructions: vec![alloc(1, 7, MemoryId::HOST, bx), recv],
                pilots: vec![],
            },
        ];
        let vs = verify_cluster(&streams);
        assert!(
            vs.iter().any(|v| matches!(
                v,
                Violation::CommMismatch { node: NodeId(1), instr: 2, detail }
                    if detail.contains("arrives from nowhere")
            )),
            "{vs:?}"
        );
    }

    // ── real pipeline: valid graphs are clean; mutations are caught ──────

    fn compile_full(
        nodes: u64,
        devices: u64,
        collectives: bool,
        direct_comm: bool,
        lookahead: bool,
        f: impl Fn(&mut TaskManager),
    ) -> (Vec<NodeStream>, BufferPool) {
        let mut tm = TaskManager::new();
        f(&mut tm);
        tm.shutdown();
        let tasks = tm.take_new_tasks();
        let mut streams = Vec::new();
        for node in 0..nodes {
            let cfg = SchedulerConfig {
                node: NodeId(node),
                num_nodes: nodes,
                num_devices: devices,
                collectives,
                direct_comm,
                lookahead,
                ..Default::default()
            };
            let mut sched = Scheduler::new(cfg, tm.buffers().clone());
            let mut instructions = Vec::new();
            let mut pilots = Vec::new();
            for t in &tasks {
                let (is, ps) = sched.process(t);
                instructions.extend(is);
                pilots.extend(ps);
            }
            let (is, ps) = sched.flush_now();
            instructions.extend(is);
            pilots.extend(ps);
            assert!(sched.take_errors().is_empty());
            assert!(sched.take_idag_errors().is_empty());
            streams.push(NodeStream { node: NodeId(node), instructions, pilots });
        }
        (streams, tm.buffers().clone())
    }

    fn nbody(tm: &mut TaskManager) {
        let r = Range::d1(256);
        let p = tm.create_buffer::<[f64; 3]>("P", r, true).id();
        let v = tm.create_buffer::<[f64; 3]>("V", r, true).id();
        for _ in 0..3 {
            tm.submit(
                TaskDecl::device("timestep", r)
                    .read(p, RangeMapper::All)
                    .read_write(v, RangeMapper::OneToOne),
            );
            tm.submit(
                TaskDecl::device("update", r)
                    .read(v, RangeMapper::OneToOne)
                    .read_write(p, RangeMapper::OneToOne),
            );
        }
    }

    #[test]
    fn nbody_pipeline_is_clean_across_configs() {
        for nodes in [1u64, 2, 4] {
            for (coll, direct) in [(true, true), (false, true), (true, false), (false, false)] {
                let (streams, buffers) = compile_full(nodes, 2, coll, direct, true, nbody);
                for s in &streams {
                    let vs = verify_stream(
                        JobId(0),
                        s.node,
                        buffers.clone(),
                        &s.instructions,
                        &s.pilots,
                    );
                    assert_eq!(
                        vs,
                        vec![],
                        "{nodes}n coll={coll} direct={direct} node {}",
                        s.node
                    );
                }
                let cl = verify_cluster(&streams);
                assert_eq!(cl, vec![], "{nodes}n coll={coll} direct={direct}");
            }
        }
    }

    #[test]
    fn dropping_a_dependency_edge_from_a_real_graph_is_caught() {
        let (streams, buffers) = compile_full(1, 2, false, true, true, nbody);
        let stream = &streams[0];
        // Find a kernel with a dataflow edge to a non-alloc producer and
        // drop exactly that edge.
        let mut mutated: Option<(Vec<InstructionRef>, u64, u64)> = None;
        let by_id: HashMap<u64, &InstructionRef> =
            stream.instructions.iter().map(|i| (i.id.0, i)).collect();
        'outer: for (pos, i) in stream.instructions.iter().enumerate() {
            if !matches!(i.kind, InstructionKind::DeviceKernel { .. }) {
                continue;
            }
            for (dep, kind) in &i.deps {
                let producer = by_id.get(&dep.0);
                let is_writer = producer.is_some_and(|p| {
                    matches!(
                        p.kind,
                        InstructionKind::DeviceKernel { .. } | InstructionKind::Copy { .. }
                    )
                });
                if *kind == DepKind::Dataflow && is_writer {
                    let mut instrs = stream.instructions.clone();
                    let pruned: Vec<_> =
                        i.deps.iter().filter(|(d, _)| d.0 != dep.0).cloned().collect();
                    instrs[pos] = Arc::new(Instruction {
                        id: i.id,
                        kind: i.kind.clone(),
                        deps: pruned,
                        task: i.task.clone(),
                    });
                    mutated = Some((instrs, dep.0, i.id.0));
                    break 'outer;
                }
            }
        }
        let (instrs, dropped_dep, victim) =
            mutated.expect("nbody graph must contain a kernel→writer dataflow edge");
        let vs = verify_stream(JobId(0), NodeId(0), buffers, &instrs, &stream.pilots);
        assert!(
            vs.iter().any(|v| matches!(
                v,
                Violation::Race { earlier, later, .. }
                    if *earlier == dropped_dep && *later == victim
            )),
            "dropping the I{dropped_dep}→I{victim} edge must race that exact pair: {vs:?}"
        );
    }

    #[test]
    fn incremental_absorb_equals_one_shot() {
        let (streams, buffers) = compile_full(2, 2, true, true, true, nbody);
        let s = &streams[0];
        let mut inc = Verifier::new(JobId(0), NodeId(0), buffers.clone());
        for chunk in s.instructions.chunks(3) {
            inc.absorb_batch(chunk, &[]);
        }
        inc.absorb_batch(&[], &s.pilots); // late pilots don't matter for reads
        let mut one = Verifier::new(JobId(0), NodeId(0), buffers.clone());
        one.absorb_batch(&s.instructions, &s.pilots);
        // Same non-pilot verdicts; the incremental run reported pilot
        // mismatches (pilots arrived after their sends) which the one-shot
        // run did not.
        let strip = |vs: Vec<Violation>| {
            vs.into_iter()
                .filter(|v| !matches!(v, Violation::PilotMismatch { .. }))
                .collect::<Vec<_>>()
        };
        assert_eq!(strip(inc.take_violations()), strip(one.take_violations()));
    }
}
