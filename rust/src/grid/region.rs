//! Regions: finite unions of disjoint boxes.

use super::{GridBox, Range};
use std::fmt;

/// A region of index space, stored as a normalized set of pairwise-disjoint
/// boxes. This is the geometry type behind every access, dependency and
/// transfer in the runtime (Celerity's `GridRegion` equivalent).
///
/// Normalization keeps boxes disjoint and greedily fuses mergeable
/// neighbours, so that e.g. the union of the two halves of a buffer is
/// represented as a single box again.
#[derive(Debug, Clone, Default)]
pub struct Region {
    boxes: Vec<GridBox>,
}

/// Equality is *semantic* (same set of elements), not structural: greedy
/// coalescing does not yield a canonical box decomposition, so two equal
/// regions may be stored as different box sets.
impl PartialEq for Region {
    fn eq(&self, other: &Region) -> bool {
        self.area() == other.area() && self.contains(other)
    }
}

impl Eq for Region {}

impl Region {
    /// The empty region.
    pub fn empty() -> Region {
        Region { boxes: Vec::new() }
    }

    /// Region covering `[0, range)`.
    pub fn full(range: Range) -> Region {
        Region::from(GridBox::full(range))
    }

    /// Construct from an arbitrary collection of (possibly overlapping)
    /// boxes; the result is normalized.
    pub fn from_boxes(boxes: impl IntoIterator<Item = GridBox>) -> Region {
        let mut r = Region::empty();
        for b in boxes {
            r.union_box_in_place(&b);
        }
        r.coalesce();
        r
    }

    /// The disjoint boxes making up this region.
    pub fn boxes(&self) -> &[GridBox] {
        &self.boxes
    }

    /// True if the region contains no elements.
    pub fn is_empty(&self) -> bool {
        self.boxes.is_empty()
    }

    /// Number of elements covered.
    pub fn area(&self) -> u64 {
        self.boxes.iter().map(|b| b.area()).sum()
    }

    /// Smallest single box covering the whole region.
    pub fn bounding_box(&self) -> GridBox {
        self.boxes
            .iter()
            .fold(GridBox::EMPTY, |acc, b| acc.bounding_union(b))
    }

    /// True if `b` is fully covered by this region.
    pub fn contains_box(&self, b: &GridBox) -> bool {
        if b.is_empty() {
            return true;
        }
        // Subtract all our boxes from b; covered iff nothing remains.
        let mut rest = vec![*b];
        for mine in &self.boxes {
            let mut next = Vec::new();
            for r in rest {
                next.extend(r.difference(mine));
            }
            rest = next;
            if rest.is_empty() {
                return true;
            }
        }
        rest.is_empty()
    }

    /// True if `other` is fully covered by this region.
    pub fn contains(&self, other: &Region) -> bool {
        other.boxes.iter().all(|b| self.contains_box(b))
    }

    /// True if the regions share at least one element.
    pub fn intersects(&self, other: &Region) -> bool {
        self.boxes
            .iter()
            .any(|a| other.boxes.iter().any(|b| a.intersects(b)))
    }

    /// Set union.
    pub fn union(&self, other: &Region) -> Region {
        let mut out = self.clone();
        for b in &other.boxes {
            out.union_box_in_place(b);
        }
        out.coalesce();
        out
    }

    /// Set intersection.
    pub fn intersection(&self, other: &Region) -> Region {
        let mut boxes = Vec::new();
        for a in &self.boxes {
            for b in &other.boxes {
                let c = a.intersection(b);
                if !c.is_empty() {
                    boxes.push(c);
                }
            }
        }
        // Our boxes are disjoint and other's boxes are disjoint, so the
        // pairwise intersections are disjoint already.
        let mut r = Region { boxes };
        r.coalesce();
        r
    }

    /// Intersection with a single box.
    pub fn intersection_box(&self, b: &GridBox) -> Region {
        let mut boxes = Vec::new();
        for a in &self.boxes {
            let c = a.intersection(b);
            if !c.is_empty() {
                boxes.push(c);
            }
        }
        let mut r = Region { boxes };
        r.coalesce();
        r
    }

    /// Set difference `self \ other`.
    pub fn difference(&self, other: &Region) -> Region {
        let mut rest = self.boxes.clone();
        for b in &other.boxes {
            let mut next = Vec::new();
            for r in rest {
                next.extend(r.difference(b));
            }
            rest = next;
            if rest.is_empty() {
                break;
            }
        }
        let mut r = Region { boxes: rest };
        r.coalesce();
        r
    }

    fn union_box_in_place(&mut self, b: &GridBox) {
        if b.is_empty() {
            return;
        }
        // Keep boxes disjoint: insert only the parts of b not yet covered.
        let mut parts = vec![*b];
        for mine in &self.boxes {
            let mut next = Vec::new();
            for p in parts {
                next.extend(p.difference(mine));
            }
            parts = next;
            if parts.is_empty() {
                return;
            }
        }
        self.boxes.extend(parts);
    }

    /// Greedily fuse mergeable boxes until a fixed point, then sort for a
    /// canonical representation (makes `==` meaningful across build orders).
    fn coalesce(&mut self) {
        let mut changed = true;
        while changed {
            changed = false;
            'outer: for i in 0..self.boxes.len() {
                for j in (i + 1)..self.boxes.len() {
                    if self.boxes[i].mergeable(&self.boxes[j]) {
                        let m = self.boxes[i].merged(&self.boxes[j]);
                        self.boxes.swap_remove(j);
                        self.boxes[i] = m;
                        changed = true;
                        break 'outer;
                    }
                }
            }
        }
        self.boxes.sort_by_key(|b| (b.min.0, b.max.0));
    }
}

impl From<GridBox> for Region {
    fn from(b: GridBox) -> Region {
        if b.is_empty() {
            Region::empty()
        } else {
            Region { boxes: vec![b] }
        }
    }
}

impl fmt::Display for Region {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, b) in self.boxes.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{b}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    #[test]
    fn union_of_halves_is_full() {
        let a = Region::from(GridBox::d1(0, 512));
        let b = Region::from(GridBox::d1(512, 1024));
        let u = a.union(&b);
        assert_eq!(u, Region::full(Range::d1(1024)));
        assert_eq!(u.boxes().len(), 1, "halves should coalesce into one box");
    }

    #[test]
    fn union_is_idempotent_and_commutative() {
        let a = Region::from_boxes([GridBox::d2((0, 0), (4, 4)), GridBox::d2((6, 0), (8, 4))]);
        let b = Region::from(GridBox::d2((2, 2), (7, 6)));
        assert_eq!(a.union(&b), b.union(&a));
        assert_eq!(a.union(&a), a);
        assert_eq!(a.union(&b).area(), 4 * 4 + 2 * 4 + 5 * 4 - (2 * 2 + 1 * 2));
    }

    #[test]
    fn intersection_and_difference_partition() {
        let a = Region::from(GridBox::d2((0, 0), (10, 10)));
        let b = Region::from(GridBox::d2((5, 5), (15, 15)));
        let i = a.intersection(&b);
        let d = a.difference(&b);
        assert_eq!(i.area() + d.area(), a.area());
        assert!(!i.intersects(&d));
        assert_eq!(i, Region::from(GridBox::d2((5, 5), (10, 10))));
    }

    #[test]
    fn contains_spanning_multiple_boxes() {
        // Region of two adjacent-but-unmergeable boxes still covers a box
        // spanning both.
        let r = Region::from_boxes([GridBox::d2((0, 0), (5, 10)), GridBox::d2((5, 2), (9, 8))]);
        assert!(r.contains_box(&GridBox::d2((3, 3), (7, 7))));
        assert!(!r.contains_box(&GridBox::d2((3, 0), (7, 7))));
    }

    #[test]
    fn empty_behaviour() {
        let e = Region::empty();
        let r = Region::full(Range::d1(4));
        assert!(e.is_empty());
        assert_eq!(e.union(&r), r);
        assert_eq!(r.intersection(&e), e);
        assert_eq!(r.difference(&e), r);
        assert_eq!(e.difference(&r), e);
        assert!(r.contains(&e));
        assert!(!e.contains(&r));
        assert!(!e.intersects(&r));
    }

    #[test]
    fn bounding_box_covers() {
        let r = Region::from_boxes([GridBox::d1(0, 2), GridBox::d1(8, 10)]);
        assert_eq!(r.bounding_box(), GridBox::d1(0, 10));
        assert_eq!(r.area(), 4);
    }

    /// Property test: region algebra obeys set-algebra laws on random inputs.
    #[test]
    fn property_set_algebra_laws() {
        let mut rng = XorShift64::new(0xC0FFEE);
        for _ in 0..200 {
            let rand_region = |rng: &mut XorShift64| {
                let n = rng.next_range(1, 4);
                Region::from_boxes((0..n).map(|_| {
                    let x0 = rng.next_below(16);
                    let y0 = rng.next_below(16);
                    let x1 = x0 + rng.next_range(1, 8);
                    let y1 = y0 + rng.next_range(1, 8);
                    GridBox::d2((x0, y0), (x1, y1))
                }))
            };
            let a = rand_region(&mut rng);
            let b = rand_region(&mut rng);

            // Inclusion–exclusion on areas.
            assert_eq!(
                a.union(&b).area() + a.intersection(&b).area(),
                a.area() + b.area()
            );
            // A \ B and A ∩ B partition A.
            assert_eq!(a.difference(&b).area() + a.intersection(&b).area(), a.area());
            // (A ∪ B) ⊇ A, B; (A ∩ B) ⊆ A, B.
            assert!(a.union(&b).contains(&a));
            assert!(a.union(&b).contains(&b));
            assert!(a.contains(&a.intersection(&b)));
            // Difference is disjoint from subtrahend.
            assert!(!a.difference(&b).intersects(&b));
            // Normalized representation: boxes pairwise disjoint.
            let u = a.union(&b);
            for (i, x) in u.boxes().iter().enumerate() {
                for y in &u.boxes()[i + 1..] {
                    assert!(!x.intersects(y));
                }
            }
            // Canonical equality: same region built in both orders.
            assert_eq!(a.union(&b), b.union(&a));
        }
    }
}
