//! Axis-aligned half-open boxes in index space.

use super::{Point, Range};
use std::fmt;

/// A half-open axis-aligned box `[min, max)` in 3-dimensional index space.
///
/// Boxes are the unit of storage inside [`super::Region`]s and the geometry
/// carried by copy-, send- and receive instructions (MPI subarray transfers
/// and SYCL rectangular copies both operate on boxes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GridBox {
    /// Inclusive lower corner.
    pub min: Point,
    /// Exclusive upper corner.
    pub max: Point,
}

impl GridBox {
    /// Construct from corners. Any degenerate axis (min >= max) yields the
    /// canonical empty box.
    pub fn new(min: Point, max: Point) -> GridBox {
        if min.all_lt(max) {
            GridBox { min, max }
        } else {
            GridBox::EMPTY
        }
    }

    /// The canonical empty box.
    pub const EMPTY: GridBox = GridBox { min: Point([0, 0, 0]), max: Point([0, 0, 0]) };

    /// The box `[0, range)` anchored at the origin.
    pub fn full(range: Range) -> GridBox {
        GridBox::new(Point::ZERO, Point(range.0))
    }

    /// 1-dimensional box `[lo, hi) × [0,1) × [0,1)`.
    pub fn d1(lo: u64, hi: u64) -> GridBox {
        GridBox::new(Point::d3(lo, 0, 0), Point::d3(hi, 1, 1))
    }

    /// 2-dimensional box.
    pub fn d2(lo: (u64, u64), hi: (u64, u64)) -> GridBox {
        GridBox::new(Point::d3(lo.0, lo.1, 0), Point::d3(hi.0, hi.1, 1))
    }

    /// 3-dimensional box.
    pub fn d3(lo: (u64, u64, u64), hi: (u64, u64, u64)) -> GridBox {
        GridBox::new(Point::d3(lo.0, lo.1, lo.2), Point::d3(hi.0, hi.1, hi.2))
    }

    /// Extent along each axis.
    pub fn range(&self) -> Range {
        Range((self.max.saturating_sub(self.min)).0)
    }

    /// Number of elements contained.
    pub fn area(&self) -> u64 {
        self.range().size()
    }

    /// True if the box contains no elements.
    pub fn is_empty(&self) -> bool {
        !self.min.all_lt(self.max)
    }

    /// True if `p` lies inside the box.
    pub fn contains_point(&self, p: Point) -> bool {
        self.min.all_le(p) && p.all_lt(self.max)
    }

    /// True if `other` is fully contained in `self`. The empty box is
    /// contained in everything.
    pub fn contains(&self, other: &GridBox) -> bool {
        other.is_empty() || (self.min.all_le(other.min) && other.max.all_le(self.max))
    }

    /// Intersection of two boxes (possibly empty).
    pub fn intersection(&self, other: &GridBox) -> GridBox {
        GridBox::new(self.min.max(other.min), self.max.min(other.max))
    }

    /// True if the boxes share at least one element.
    pub fn intersects(&self, other: &GridBox) -> bool {
        !self.intersection(other).is_empty()
    }

    /// Smallest box containing both inputs. Empty inputs are ignored.
    pub fn bounding_union(&self, other: &GridBox) -> GridBox {
        if self.is_empty() {
            *other
        } else if other.is_empty() {
            *self
        } else {
            GridBox::new(self.min.min(other.min), self.max.max(other.max))
        }
    }

    /// Subtract `other` from `self`, producing up to 6 disjoint boxes that
    /// cover `self \ other`. The decomposition slabs axis-by-axis: for each
    /// axis the parts of `self` strictly below/above `other` are emitted and
    /// the remainder is clamped to `other`'s extent on that axis.
    pub fn difference(&self, other: &GridBox) -> Vec<GridBox> {
        let cut = self.intersection(other);
        if cut.is_empty() {
            return if self.is_empty() { vec![] } else { vec![*self] };
        }
        if other.contains(self) {
            return vec![];
        }
        let mut out = Vec::new();
        let mut rest = *self;
        for d in 0..3 {
            if rest.min[d] < cut.min[d] {
                let mut below = rest;
                below.max[d] = cut.min[d];
                out.push(below);
                rest.min[d] = cut.min[d];
            }
            if cut.max[d] < rest.max[d] {
                let mut above = rest;
                above.min[d] = cut.max[d];
                out.push(above);
                rest.max[d] = cut.max[d];
            }
        }
        out
    }

    /// True if the two boxes can be fused into one box: they must span the
    /// same extent on every axis except one, along which they are adjacent
    /// or overlapping.
    pub fn mergeable(&self, other: &GridBox) -> bool {
        if self.is_empty() || other.is_empty() {
            return true;
        }
        let mut off_axis = None;
        for d in 0..3 {
            if self.min[d] != other.min[d] || self.max[d] != other.max[d] {
                if off_axis.is_some() {
                    return false;
                }
                off_axis = Some(d);
            }
        }
        match off_axis {
            None => true, // identical
            Some(d) => self.max[d] >= other.min[d] && other.max[d] >= self.min[d],
        }
    }

    /// Fuse two [`mergeable`](GridBox::mergeable) boxes.
    pub fn merged(&self, other: &GridBox) -> GridBox {
        debug_assert!(self.mergeable(other));
        self.bounding_union(other)
    }

    /// Translate the box by `offset` (component-wise add).
    pub fn translated(&self, offset: Point) -> GridBox {
        if self.is_empty() {
            GridBox::EMPTY
        } else {
            GridBox { min: self.min + offset, max: self.max + offset }
        }
    }

    /// Grow the box by `margin` on every side, clamped to `[0, clamp)`.
    /// This is the geometry of a neighborhood range mapper.
    pub fn dilated(&self, margin: Range, clamp: Range) -> GridBox {
        if self.is_empty() {
            return GridBox::EMPTY;
        }
        let mut b = *self;
        for d in 0..3 {
            // margin uses Range semantics: extent 1 on unused axes means 0
            // dilation there only if the axis is degenerate in clamp space.
            let m = margin[d];
            b.min[d] = b.min[d].saturating_sub(m);
            b.max[d] = (b.max[d] + m).min(clamp[d]);
        }
        b
    }
}

impl fmt::Display for GridBox {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{} - {}]", self.min, self.max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degenerate_constructor_is_empty() {
        assert!(GridBox::d1(5, 5).is_empty());
        assert!(GridBox::d1(7, 3).is_empty());
        assert_eq!(GridBox::d1(7, 3), GridBox::EMPTY);
    }

    #[test]
    fn area_and_range() {
        let b = GridBox::d2((1, 2), (4, 6));
        assert_eq!(b.range(), Range::d2(3, 4));
        assert_eq!(b.area(), 12);
        assert_eq!(GridBox::full(Range::d1(10)).area(), 10);
    }

    #[test]
    fn containment() {
        let outer = GridBox::d2((0, 0), (10, 10));
        let inner = GridBox::d2((2, 3), (5, 7));
        assert!(outer.contains(&inner));
        assert!(!inner.contains(&outer));
        assert!(outer.contains(&GridBox::EMPTY));
        assert!(inner.contains_point(Point::d2(2, 3)));
        assert!(!inner.contains_point(Point::d2(5, 7)));
    }

    #[test]
    fn intersection_cases() {
        let a = GridBox::d1(0, 10);
        let b = GridBox::d1(5, 15);
        assert_eq!(a.intersection(&b), GridBox::d1(5, 10));
        assert!(a.intersects(&b));
        // adjacent boxes do not intersect (half-open)
        assert!(!GridBox::d1(0, 5).intersects(&GridBox::d1(5, 10)));
    }

    #[test]
    fn difference_disjoint_and_contained() {
        let a = GridBox::d1(0, 10);
        assert_eq!(a.difference(&GridBox::d1(20, 30)), vec![a]);
        assert!(a.difference(&GridBox::d1(0, 10)).is_empty());
        assert!(a.difference(&GridBox::d1(0, 100)).is_empty());
    }

    #[test]
    fn difference_partitions_exactly() {
        // 2D case: remove center from a 10x10 box → 4 slabs.
        let a = GridBox::d2((0, 0), (10, 10));
        let hole = GridBox::d2((3, 3), (7, 7));
        let parts = a.difference(&hole);
        let total: u64 = parts.iter().map(|b| b.area()).sum();
        assert_eq!(total, 100 - 16);
        // Parts are disjoint from each other and from the hole.
        for (i, p) in parts.iter().enumerate() {
            assert!(!p.intersects(&hole));
            for q in &parts[i + 1..] {
                assert!(!p.intersects(q), "{p} intersects {q}");
            }
        }
    }

    #[test]
    fn difference_3d_corner() {
        let a = GridBox::d3((0, 0, 0), (4, 4, 4));
        let corner = GridBox::d3((0, 0, 0), (2, 2, 2));
        let parts = a.difference(&corner);
        let total: u64 = parts.iter().map(|b| b.area()).sum();
        assert_eq!(total, 64 - 8);
    }

    #[test]
    fn mergeable_rules() {
        // adjacent along x, same y extent
        assert!(GridBox::d2((0, 0), (5, 4)).mergeable(&GridBox::d2((5, 0), (9, 4))));
        // gap along x
        assert!(!GridBox::d2((0, 0), (4, 4)).mergeable(&GridBox::d2((5, 0), (9, 4))));
        // different y extents
        assert!(!GridBox::d2((0, 0), (5, 4)).mergeable(&GridBox::d2((5, 0), (9, 5))));
        // identical boxes merge
        let b = GridBox::d1(2, 4);
        assert!(b.mergeable(&b));
        assert_eq!(b.merged(&b), b);
        // merged result
        assert_eq!(
            GridBox::d1(0, 5).merged(&GridBox::d1(5, 9)),
            GridBox::d1(0, 9)
        );
    }

    #[test]
    fn dilation_clamps() {
        let b = GridBox::d1(0, 3);
        let d = b.dilated(Range::d1(2), Range::d1(8));
        assert_eq!(d, GridBox::d1(0, 5));
        let b2 = GridBox::d1(6, 8);
        assert_eq!(b2.dilated(Range::d1(3), Range::d1(8)), GridBox::d1(3, 8));
    }

    #[test]
    fn translation() {
        assert_eq!(
            GridBox::d2((1, 1), (2, 2)).translated(Point::d2(3, 4)),
            GridBox::d2((4, 5), (5, 6))
        );
        assert_eq!(GridBox::EMPTY.translated(Point::d1(5)), GridBox::EMPTY);
    }
}
