//! Map from index-space regions to values.
//!
//! `RegionMap<T>` assigns a value of `T` to every element of a fixed extent.
//! It is the workhorse behind all runtime bookkeeping: last-writer tracking
//! in the TDAG, original-producer/ownership tracking in the CDAG, and
//! up-to-date-memories coherence tracking in the IDAG. Updates overwrite a
//! region with a new value; queries return the covering `(box, value)`
//! fragments of a region.

use super::{GridBox, Range, Region};

/// A total map from `[0, extent)` to `T`, stored as disjoint `(box, value)`
/// entries. Adjacent entries holding equal values are coalesced.
#[derive(Debug, Clone)]
pub struct RegionMap<T> {
    extent: GridBox,
    entries: Vec<(GridBox, T)>,
}

impl<T: Clone + PartialEq> RegionMap<T> {
    /// Create a map over `[0, extent)`, initially mapping everything to
    /// `default`.
    pub fn new(extent: Range, default: T) -> Self {
        let full = GridBox::full(extent);
        RegionMap {
            extent: full,
            entries: if full.is_empty() { vec![] } else { vec![(full, default)] },
        }
    }

    /// The extent this map covers.
    pub fn extent(&self) -> GridBox {
        self.extent
    }

    /// Number of internal `(box, value)` fragments (diagnostics; the horizon
    /// mechanism exists to keep this bounded).
    pub fn fragments(&self) -> usize {
        self.entries.len()
    }

    /// Overwrite `region ∩ extent` with `value`.
    pub fn update_region(&mut self, region: &Region, value: T) {
        for b in region.boxes() {
            self.update_box(b, value.clone());
        }
    }

    /// Overwrite `b ∩ extent` with `value`.
    pub fn update_box(&mut self, b: &GridBox, value: T) {
        let b = b.intersection(&self.extent);
        if b.is_empty() {
            return;
        }
        let mut next = Vec::with_capacity(self.entries.len() + 1);
        for (eb, ev) in self.entries.drain(..) {
            if eb.intersects(&b) {
                for rest in eb.difference(&b) {
                    next.push((rest, ev.clone()));
                }
            } else {
                next.push((eb, ev));
            }
        }
        next.push((b, value));
        self.entries = next;
        self.coalesce();
    }

    /// Apply `f` to the value over `region ∩ extent`, splitting fragments as
    /// needed. Used e.g. to add a memory id to coherence sets.
    pub fn apply_to_region(&mut self, region: &Region, f: impl Fn(&T) -> T) {
        let mut next: Vec<(GridBox, T)> = Vec::with_capacity(self.entries.len());
        for (eb, ev) in self.entries.drain(..) {
            let inside = region.intersection_box(&eb);
            if inside.is_empty() {
                next.push((eb, ev));
                continue;
            }
            // Fragments inside the region get the new value...
            for ib in inside.boxes() {
                next.push((*ib, f(&ev)));
            }
            // ...fragments outside keep the old one.
            let outside = Region::from(eb).difference(&inside);
            for ob in outside.boxes() {
                next.push((*ob, ev.clone()));
            }
        }
        self.entries = next;
        self.coalesce();
    }

    /// All `(box, value)` fragments covering `region ∩ extent`.
    pub fn query_region(&self, region: &Region) -> Vec<(GridBox, T)> {
        let mut out = Vec::new();
        for (eb, ev) in &self.entries {
            let inside = region.intersection_box(eb);
            for ib in inside.boxes() {
                out.push((*ib, ev.clone()));
            }
        }
        out
    }

    /// All `(box, value)` fragments covering `b ∩ extent`.
    pub fn query_box(&self, b: &GridBox) -> Vec<(GridBox, T)> {
        let mut out = Vec::new();
        for (eb, ev) in &self.entries {
            let c = eb.intersection(b);
            if !c.is_empty() {
                out.push((c, ev.clone()));
            }
        }
        out
    }

    /// The value at a single point, if inside the extent.
    pub fn at(&self, p: super::Point) -> Option<&T> {
        self.entries
            .iter()
            .find(|(b, _)| b.contains_point(p))
            .map(|(_, v)| v)
    }

    /// The region over which `pred` holds.
    pub fn region_where(&self, pred: impl Fn(&T) -> bool) -> Region {
        Region::from_boxes(
            self.entries
                .iter()
                .filter(|(_, v)| pred(v))
                .map(|(b, _)| *b),
        )
    }

    /// Iterate over all fragments.
    pub fn iter(&self) -> impl Iterator<Item = &(GridBox, T)> {
        self.entries.iter()
    }

    /// Fuse mergeable fragments holding equal values.
    fn coalesce(&mut self) {
        let mut changed = true;
        while changed {
            changed = false;
            'outer: for i in 0..self.entries.len() {
                for j in (i + 1)..self.entries.len() {
                    if self.entries[i].1 == self.entries[j].1
                        && self.entries[i].0.mergeable(&self.entries[j].0)
                    {
                        let m = self.entries[i].0.merged(&self.entries[j].0);
                        self.entries.swap_remove(j);
                        self.entries[i].0 = m;
                        changed = true;
                        break 'outer;
                    }
                }
            }
        }
        self.entries.sort_by_key(|(b, _)| (b.min.0, b.max.0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::Point;

    #[test]
    fn fresh_map_is_single_fragment() {
        let m = RegionMap::new(Range::d1(100), 0u32);
        assert_eq!(m.fragments(), 1);
        assert_eq!(m.at(Point::d1(50)), Some(&0));
        assert_eq!(m.at(Point::d1(100)), None);
    }

    #[test]
    fn update_splits_and_queries_fragments() {
        let mut m = RegionMap::new(Range::d1(100), 0u32);
        m.update_box(&GridBox::d1(20, 40), 1);
        assert_eq!(m.fragments(), 3);
        assert_eq!(m.at(Point::d1(10)), Some(&0));
        assert_eq!(m.at(Point::d1(30)), Some(&1));
        assert_eq!(m.at(Point::d1(50)), Some(&0));

        let q = m.query_box(&GridBox::d1(30, 60));
        let total: u64 = q.iter().map(|(b, _)| b.area()).sum();
        assert_eq!(total, 30);
        assert!(q.contains(&(GridBox::d1(30, 40), 1)));
        assert!(q.contains(&(GridBox::d1(40, 60), 0)));
    }

    #[test]
    fn equal_values_coalesce_back() {
        let mut m = RegionMap::new(Range::d1(100), 0u32);
        m.update_box(&GridBox::d1(20, 40), 1);
        m.update_box(&GridBox::d1(20, 40), 0);
        assert_eq!(m.fragments(), 1);
    }

    #[test]
    fn update_clamps_to_extent() {
        let mut m = RegionMap::new(Range::d1(10), 0u32);
        m.update_box(&GridBox::d1(5, 100), 7);
        assert_eq!(m.at(Point::d1(9)), Some(&7));
        let covered: u64 = m.iter().map(|(b, _)| b.area()).sum();
        assert_eq!(covered, 10, "map must stay total over its extent");
    }

    #[test]
    fn apply_to_region_modifies_only_inside() {
        let mut m = RegionMap::new(Range::d1(10), vec![0u64]);
        m.apply_to_region(&Region::from(GridBox::d1(3, 7)), |v| {
            let mut v = v.clone();
            v.push(1);
            v
        });
        assert_eq!(m.at(Point::d1(2)), Some(&vec![0]));
        assert_eq!(m.at(Point::d1(5)), Some(&vec![0, 1]));
        assert_eq!(m.at(Point::d1(8)), Some(&vec![0]));
    }

    #[test]
    fn region_where_inverts_updates() {
        let mut m = RegionMap::new(Range::d2(8, 8), false);
        let r = Region::from_boxes([GridBox::d2((0, 0), (4, 4)), GridBox::d2((4, 4), (8, 8))]);
        m.update_region(&r, true);
        assert_eq!(m.region_where(|v| *v), r);
        assert_eq!(m.region_where(|v| !*v).area(), 64 - 32);
    }

    #[test]
    fn map_remains_total_partition_under_random_updates() {
        use crate::util::XorShift64;
        let mut rng = XorShift64::new(77);
        let mut m = RegionMap::new(Range::d2(32, 32), 0u64);
        for step in 0..200 {
            let x0 = rng.next_below(32);
            let y0 = rng.next_below(32);
            let x1 = x0 + rng.next_range(1, 16);
            let y1 = y0 + rng.next_range(1, 16);
            m.update_box(&GridBox::d2((x0, y0), (x1, y1)), step);
            // Total area invariant.
            let covered: u64 = m.iter().map(|(b, _)| b.area()).sum();
            assert_eq!(covered, 32 * 32);
            // Disjointness invariant.
            let frags: Vec<_> = m.iter().map(|(b, _)| *b).collect();
            for (i, a) in frags.iter().enumerate() {
                for b in &frags[i + 1..] {
                    assert!(!a.intersects(b));
                }
            }
        }
    }
}
