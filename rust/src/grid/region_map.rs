//! Map from index-space regions to values.
//!
//! `RegionMap<T>` assigns a value of `T` to every element of a fixed extent.
//! It is the workhorse behind all runtime bookkeeping: last-writer tracking
//! in the TDAG, original-producer/ownership tracking in the CDAG, and
//! up-to-date-memories coherence tracking in the IDAG. Updates overwrite a
//! region with a new value; queries return the covering `(box, value)`
//! fragments of a region.
//!
//! # Indexing scheme (scheduler hot path)
//!
//! This map sits in the inner loop of all three graph generators, so every
//! operation must avoid rescanning and re-cloning the whole fragment list
//! (§4.1: "as little time as possible must be spent" in the scheduler):
//!
//! - **Sorted interval index.** Fragments are kept sorted by their `min`
//!   corner (major dimension first). Together with `max_span` — an upper
//!   bound on any fragment's major-dimension extent — a query for box `b`
//!   binary-searches the *candidate window* of fragments whose dimension-0
//!   interval can intersect `b`, then applies a bounding-box check per
//!   candidate. Disjoint workloads (the common case: per-row updates,
//!   per-chunk queries) touch `O(log n + answer)` fragments instead of all.
//! - **`Cow`-style value sharing.** Values are stored behind `Arc<T>`, so
//!   splitting a fragment copies a pointer — never the payload. This matters
//!   for reader-set tracking (`RegionMap<Vec<InstructionId>>`) where the old
//!   flat representation deep-cloned every reader list on every split.
//! - **Batched overwrites.** [`RegionMap::update_boxes`] applies many
//!   `(box, value)` overwrites in one partition pass; the instruction
//!   generator uses it when a single command produces many fragments.
//! - **Borrowing visitors.** [`RegionMap::for_each_intersecting`] /
//!   [`RegionMap::for_each_in_region`] visit covering fragments without
//!   allocating or cloning values; `query_box`/`query_region` remain as
//!   owned-result conveniences on top.

use super::{GridBox, Point, Range, Region};
use std::sync::Arc;

/// Coalescing needs a cheap "same value?" check; pointer equality
/// short-circuits the deep comparison for fragments sharing one `Arc`.
fn val_eq<T: PartialEq>(a: &Arc<T>, b: &Arc<T>) -> bool {
    Arc::ptr_eq(a, b) || **a == **b
}

/// A total map from `[0, extent)` to `T`, stored as disjoint `(box, value)`
/// entries. Adjacent entries holding equal values are coalesced.
#[derive(Debug, Clone)]
pub struct RegionMap<T> {
    extent: GridBox,
    /// Disjoint fragments sorted by `min` (lexicographic, dimension 0
    /// first). Two disjoint non-empty boxes never share a `min` corner, so
    /// the key is unique. Values are `Arc`-shared across splits.
    entries: Vec<(GridBox, Arc<T>)>,
    /// Upper bound on `max[0] - min[0]` over all entries (monotone; never
    /// recomputed on removal). Bounds the candidate window of the interval
    /// index.
    max_span: u64,
}

impl<T: Clone + PartialEq> RegionMap<T> {
    /// Create a map over `[0, extent)`, initially mapping everything to
    /// `default`.
    pub fn new(extent: Range, default: T) -> Self {
        let full = GridBox::full(extent);
        RegionMap {
            extent: full,
            entries: if full.is_empty() { vec![] } else { vec![(full, Arc::new(default))] },
            max_span: if full.is_empty() { 0 } else { full.max[0] - full.min[0] },
        }
    }

    /// The extent this map covers.
    pub fn extent(&self) -> GridBox {
        self.extent
    }

    /// Number of internal `(box, value)` fragments (diagnostics; the horizon
    /// mechanism exists to keep this bounded).
    pub fn fragments(&self) -> usize {
        self.entries.len()
    }

    /// The `[lo, hi)` entry window whose dimension-0 intervals can intersect
    /// `b`. Candidates still need a per-entry bounding-box check.
    fn window(&self, b: &GridBox) -> (usize, usize) {
        if b.is_empty() || self.entries.is_empty() {
            return (0, 0);
        }
        let span = self.max_span;
        let lo = self
            .entries
            .partition_point(|(e, _)| e.min[0].saturating_add(span) <= b.min[0]);
        let hi = self.entries.partition_point(|(e, _)| e.min[0] < b.max[0]);
        (lo, hi.max(lo))
    }

    /// Index of the entry whose box is exactly `b`, if still present.
    fn find_exact(&self, b: &GridBox) -> Option<usize> {
        let pos = self.entries.partition_point(|(e, _)| e.min.0 < b.min.0);
        match self.entries.get(pos) {
            Some((eb, _)) if eb == b => Some(pos),
            _ => None,
        }
    }

    /// Insert fragments, keeping the sort order and `max_span` invariants.
    /// Cost is `O(k log k + affected range)` — the fragments are sorted
    /// among themselves and merged into the key range they span, instead of
    /// re-sorting the whole entry vector.
    fn insert_all(&mut self, mut frags: Vec<(GridBox, Arc<T>)>) {
        if frags.is_empty() {
            return;
        }
        for (b, _) in &frags {
            self.max_span = self.max_span.max(b.max[0] - b.min[0]);
        }
        if frags.len() == 1 {
            let (b, v) = frags.into_iter().next().expect("len checked above");
            let pos = self.entries.partition_point(|(e, _)| e.min.0 < b.min.0);
            self.entries.insert(pos, (b, v));
            return;
        }
        frags.sort_unstable_by_key(|(b, _)| b.min.0);
        let lo_key = frags.first().expect("nonempty: len checked above").0.min.0;
        let hi_key = frags.last().expect("nonempty: len checked above").0.min.0;
        let r0 = self.entries.partition_point(|(e, _)| e.min.0 < lo_key);
        let r1 = self.entries.partition_point(|(e, _)| e.min.0 <= hi_key);
        let old: Vec<(GridBox, Arc<T>)> = self.entries.drain(r0..r1).collect();
        let mut merged = Vec::with_capacity(old.len() + frags.len());
        let mut a = old.into_iter().peekable();
        let mut b = frags.into_iter().peekable();
        loop {
            match (a.peek(), b.peek()) {
                (Some(x), Some(y)) => {
                    if x.0.min.0 <= y.0.min.0 {
                        merged.push(a.next().expect("peeked Some"));
                    } else {
                        merged.push(b.next().expect("peeked Some"));
                    }
                }
                (Some(_), None) => merged.push(a.next().expect("peeked Some")),
                (None, Some(_)) => merged.push(b.next().expect("peeked Some")),
                (None, None) => break,
            }
        }
        self.entries.splice(r0..r0, merged);
    }

    /// Restore the exactness of `max_span` after removing entries. `max_span`
    /// must stay *attained* by a live entry, or the window's lower bound
    /// degrades to a linear scan (the seed fragment spans the full extent, so
    /// a pinned bound would make the index inert forever after the first
    /// split). Recomputes only when a removed entry attained the prior bound.
    fn refresh_max_span(&mut self, prior_span: u64, removed: &[(GridBox, Arc<T>)]) {
        if removed.iter().all(|(b, _)| b.max[0] - b.min[0] < prior_span) {
            return;
        }
        self.max_span = self
            .entries
            .iter()
            .map(|(b, _)| b.max[0] - b.min[0])
            .max()
            .unwrap_or(0);
    }

    /// Overwrite `region ∩ extent` with `value`.
    pub fn update_region(&mut self, region: &Region, value: T) {
        let v = Arc::new(value);
        let updates: Vec<(GridBox, Arc<T>)> = region
            .boxes()
            .iter()
            .map(|b| b.intersection(&self.extent))
            .filter(|b| !b.is_empty())
            .map(|b| (b, v.clone()))
            .collect();
        self.overwrite(updates);
    }

    /// Overwrite `b ∩ extent` with `value`.
    pub fn update_box(&mut self, b: &GridBox, value: T) {
        let b = b.intersection(&self.extent);
        if b.is_empty() {
            return;
        }
        self.overwrite(vec![(b, Arc::new(value))]);
    }

    /// Overwrite many `(box, value)` pairs in a single partition pass. On
    /// overlap between update boxes, the later pair wins (callers usually
    /// pass disjoint boxes — e.g. the producer-split fragments of one
    /// command). Boxes are clamped to the extent.
    pub fn update_boxes(&mut self, updates: impl IntoIterator<Item = (GridBox, T)>) {
        let updates: Vec<(GridBox, Arc<T>)> = updates
            .into_iter()
            .map(|(b, v)| (b.intersection(&self.extent), Arc::new(v)))
            .filter(|(b, _)| !b.is_empty())
            .collect();
        self.overwrite(updates);
    }

    /// Core overwrite: one pass over the candidate window, value pointers
    /// shared into split fragments.
    fn overwrite(&mut self, updates: Vec<(GridBox, Arc<T>)>) {
        if updates.is_empty() {
            return;
        }
        let cover = updates
            .iter()
            .fold(GridBox::EMPTY, |acc, (b, _)| acc.bounding_union(b));
        let prior_span = self.max_span;
        let (lo, hi) = self.window(&cover);

        // Extract the entries hit by any update box (stable compaction of
        // the untouched remainder).
        let mut removed: Vec<(GridBox, Arc<T>)> = Vec::new();
        let mut keep = lo;
        for r in lo..hi {
            if updates.iter().any(|(u, _)| u.intersects(&self.entries[r].0)) {
                removed.push(self.entries[r].clone());
            } else {
                self.entries.swap(keep, r);
                keep += 1;
            }
        }
        self.entries.drain(keep..hi);

        // Surviving fragments of the removed entries keep their (shared)
        // value pointer.
        let mut frags: Vec<(GridBox, Arc<T>)> = Vec::new();
        for (eb, ev) in &removed {
            let mut parts = vec![*eb];
            for (u, _) in &updates {
                let mut next = Vec::new();
                for p in parts {
                    next.extend(p.difference(u));
                }
                parts = next;
                if parts.is_empty() {
                    break;
                }
            }
            frags.extend(parts.into_iter().map(|p| (p, ev.clone())));
        }
        // The update boxes themselves; later updates win on overlap.
        for (i, (u, v)) in updates.iter().enumerate() {
            let mut parts = vec![*u];
            for (later, _) in &updates[i + 1..] {
                let mut next = Vec::new();
                for p in parts {
                    next.extend(p.difference(later));
                }
                parts = next;
                if parts.is_empty() {
                    break;
                }
            }
            frags.extend(parts.into_iter().map(|p| (p, v.clone())));
        }

        let seeds: Vec<GridBox> = frags.iter().map(|(b, _)| *b).collect();
        self.insert_all(frags);
        self.refresh_max_span(prior_span, &removed);
        self.coalesce_around(seeds);
    }

    /// Apply `f` to the value over `region ∩ extent`, splitting fragments as
    /// needed. Used e.g. to add a memory id to coherence sets. Fragments
    /// fully inside the region are rewritten in place (no splitting, no
    /// clone of the untouched remainder).
    pub fn apply_to_region(&mut self, region: &Region, f: impl Fn(&T) -> T) {
        if region.is_empty() || self.entries.is_empty() {
            return;
        }
        let bb = region.bounding_box();
        let prior_span = self.max_span;
        let (lo, hi) = self.window(&bb);
        let mut removed: Vec<(GridBox, Arc<T>)> = Vec::new();
        let mut seeds: Vec<GridBox> = Vec::new();
        let mut keep = lo;
        for r in lo..hi {
            let eb = self.entries[r].0;
            let inside = region.intersection_box(&eb);
            if inside.is_empty() {
                self.entries.swap(keep, r);
                keep += 1;
            } else if inside.area() == eb.area() {
                // Fully covered: rewrite in place.
                let nv = f(&self.entries[r].1);
                if nv != *self.entries[r].1 {
                    self.entries[r].1 = Arc::new(nv);
                    seeds.push(eb);
                }
                self.entries.swap(keep, r);
                keep += 1;
            } else {
                removed.push(self.entries[r].clone());
            }
        }
        self.entries.drain(keep..hi);

        let mut frags: Vec<(GridBox, Arc<T>)> = Vec::new();
        for (eb, ev) in &removed {
            let inside = region.intersection_box(eb);
            let nv = Arc::new(f(ev));
            for ib in inside.boxes() {
                frags.push((*ib, nv.clone()));
            }
            for ob in Region::from(*eb).difference(&inside).boxes() {
                frags.push((*ob, ev.clone()));
            }
        }
        seeds.extend(frags.iter().map(|(b, _)| *b));
        self.insert_all(frags);
        self.refresh_max_span(prior_span, &removed);
        self.coalesce_around(seeds);
    }

    /// Visit the `(fragment ∩ b, value)` pairs covering `b ∩ extent`,
    /// without cloning values.
    pub fn for_each_intersecting(&self, b: &GridBox, mut f: impl FnMut(GridBox, &T)) {
        let (lo, hi) = self.window(b);
        for (eb, ev) in &self.entries[lo..hi] {
            let c = eb.intersection(b);
            if !c.is_empty() {
                f(c, ev);
            }
        }
    }

    /// Visit the `(box, value)` fragments covering `region ∩ extent`,
    /// without cloning values.
    pub fn for_each_in_region(&self, region: &Region, mut f: impl FnMut(GridBox, &T)) {
        if region.boxes().len() == 1 {
            self.for_each_intersecting(&region.boxes()[0], f);
            return;
        }
        let bb = region.bounding_box();
        let (lo, hi) = self.window(&bb);
        for (eb, ev) in &self.entries[lo..hi] {
            if !eb.intersects(&bb) {
                continue;
            }
            let inside = region.intersection_box(eb);
            for ib in inside.boxes() {
                f(*ib, ev);
            }
        }
    }

    /// All `(box, value)` fragments covering `region ∩ extent`.
    pub fn query_region(&self, region: &Region) -> Vec<(GridBox, T)> {
        let mut out = Vec::new();
        self.for_each_in_region(region, |b, v| out.push((b, v.clone())));
        out
    }

    /// All `(box, value)` fragments covering `b ∩ extent`.
    pub fn query_box(&self, b: &GridBox) -> Vec<(GridBox, T)> {
        let mut out = Vec::new();
        self.for_each_intersecting(b, |c, v| out.push((c, v.clone())));
        out
    }

    /// The value at a single point, if inside the extent.
    pub fn at(&self, p: Point) -> Option<&T> {
        let pb = GridBox { min: p, max: Point([p[0] + 1, p[1] + 1, p[2] + 1]) };
        let (lo, hi) = self.window(&pb);
        self.entries[lo..hi]
            .iter()
            .find(|(b, _)| b.contains_point(p))
            .map(|(_, v)| &**v)
    }

    /// The region over which `pred` holds.
    pub fn region_where(&self, pred: impl Fn(&T) -> bool) -> Region {
        Region::from_boxes(
            self.entries
                .iter()
                .filter(|(_, v)| pred(v))
                .map(|(b, _)| *b),
        )
    }

    /// Iterate over all fragments.
    pub fn iter(&self) -> impl Iterator<Item = (&GridBox, &T)> {
        self.entries.iter().map(|(b, v)| (b, &**v))
    }

    /// Fuse mergeable equal-valued fragments, looking only around the given
    /// seed boxes (the fragments an update just touched). Partners of a box
    /// share or touch its dimension-0 interval, so they lie inside the
    /// windowed neighborhood — no global `O(n²)` fixpoint scan.
    fn coalesce_around(&mut self, mut work: Vec<GridBox>) {
        while let Some(b) = work.pop() {
            let Some(i) = self.find_exact(&b) else { continue };
            let probe = GridBox {
                min: Point([b.min[0].saturating_sub(1), b.min[1], b.min[2]]),
                max: Point([b.max[0].saturating_add(1), b.max[1], b.max[2]]),
            };
            let (lo, hi) = self.window(&probe);
            let (ib, iv) = self.entries[i].clone();
            let partner = self.entries[lo..hi]
                .iter()
                .enumerate()
                .map(|(off, e)| (lo + off, e))
                .find(|(j, (jb, jv))| *j != i && ib.mergeable(jb) && val_eq(&iv, jv))
                .map(|(j, _)| j);
            if let Some(j) = partner {
                let jb = self.entries[j].0;
                let (hi_idx, lo_idx) = if i > j { (i, j) } else { (j, i) };
                self.entries.remove(hi_idx);
                self.entries.remove(lo_idx);
                let m = ib.merged(&jb);
                self.max_span = self.max_span.max(m.max[0] - m.min[0]);
                let pos = self.entries.partition_point(|(e, _)| e.min.0 < m.min.0);
                self.entries.insert(pos, (m, iv));
                work.push(m);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    #[test]
    fn fresh_map_is_single_fragment() {
        let m = RegionMap::new(Range::d1(100), 0u32);
        assert_eq!(m.fragments(), 1);
        assert_eq!(m.at(Point::d1(50)), Some(&0));
        assert_eq!(m.at(Point::d1(100)), None);
    }

    #[test]
    fn update_splits_and_queries_fragments() {
        let mut m = RegionMap::new(Range::d1(100), 0u32);
        m.update_box(&GridBox::d1(20, 40), 1);
        assert_eq!(m.fragments(), 3);
        assert_eq!(m.at(Point::d1(10)), Some(&0));
        assert_eq!(m.at(Point::d1(30)), Some(&1));
        assert_eq!(m.at(Point::d1(50)), Some(&0));

        let q = m.query_box(&GridBox::d1(30, 60));
        let total: u64 = q.iter().map(|(b, _)| b.area()).sum();
        assert_eq!(total, 30);
        assert!(q.contains(&(GridBox::d1(30, 40), 1)));
        assert!(q.contains(&(GridBox::d1(40, 60), 0)));
    }

    #[test]
    fn equal_values_coalesce_back() {
        let mut m = RegionMap::new(Range::d1(100), 0u32);
        m.update_box(&GridBox::d1(20, 40), 1);
        m.update_box(&GridBox::d1(20, 40), 0);
        assert_eq!(m.fragments(), 1);
    }

    #[test]
    fn update_clamps_to_extent() {
        let mut m = RegionMap::new(Range::d1(10), 0u32);
        m.update_box(&GridBox::d1(5, 100), 7);
        assert_eq!(m.at(Point::d1(9)), Some(&7));
        let covered: u64 = m.iter().map(|(b, _)| b.area()).sum();
        assert_eq!(covered, 10, "map must stay total over its extent");
    }

    #[test]
    fn apply_to_region_modifies_only_inside() {
        let mut m = RegionMap::new(Range::d1(10), vec![0u64]);
        m.apply_to_region(&Region::from(GridBox::d1(3, 7)), |v| {
            let mut v = v.clone();
            v.push(1);
            v
        });
        assert_eq!(m.at(Point::d1(2)), Some(&vec![0]));
        assert_eq!(m.at(Point::d1(5)), Some(&vec![0, 1]));
        assert_eq!(m.at(Point::d1(8)), Some(&vec![0]));
    }

    #[test]
    fn region_where_inverts_updates() {
        let mut m = RegionMap::new(Range::d2(8, 8), false);
        let r = Region::from_boxes([GridBox::d2((0, 0), (4, 4)), GridBox::d2((4, 4), (8, 8))]);
        m.update_region(&r, true);
        assert_eq!(m.region_where(|v| *v), r);
        assert_eq!(m.region_where(|v| !*v).area(), 64 - 32);
    }

    #[test]
    fn update_boxes_applies_batch_with_later_wins() {
        let mut m = RegionMap::new(Range::d1(100), 0u32);
        m.update_boxes([
            (GridBox::d1(0, 50), 1),
            (GridBox::d1(60, 80), 2),
            (GridBox::d1(40, 70), 3), // overlaps both earlier boxes; wins
        ]);
        assert_eq!(m.at(Point::d1(10)), Some(&1));
        assert_eq!(m.at(Point::d1(45)), Some(&3));
        assert_eq!(m.at(Point::d1(65)), Some(&3));
        assert_eq!(m.at(Point::d1(75)), Some(&2));
        assert_eq!(m.at(Point::d1(90)), Some(&0));
        let covered: u64 = m.iter().map(|(b, _)| b.area()).sum();
        assert_eq!(covered, 100);
    }

    #[test]
    fn visitors_match_owned_queries() {
        let mut m = RegionMap::new(Range::d2(16, 16), 0u32);
        m.update_box(&GridBox::d2((2, 2), (10, 10)), 1);
        m.update_box(&GridBox::d2((5, 5), (8, 14)), 2);
        let probe = GridBox::d2((0, 0), (12, 12));
        let mut visited: Vec<(GridBox, u32)> = Vec::new();
        m.for_each_intersecting(&probe, |b, v| visited.push((b, *v)));
        let owned = m.query_box(&probe);
        assert_eq!(visited, owned);

        let region =
            Region::from_boxes([GridBox::d2((0, 0), (6, 6)), GridBox::d2((9, 9), (16, 16))]);
        let mut visited: Vec<(GridBox, u32)> = Vec::new();
        m.for_each_in_region(&region, |b, v| visited.push((b, *v)));
        let owned = m.query_region(&region);
        assert_eq!(visited, owned);
        let total: u64 = visited.iter().map(|(b, _)| b.area()).sum();
        assert_eq!(total, region.area());
    }

    fn check_invariants<T: Clone + PartialEq + std::fmt::Debug>(m: &RegionMap<T>) {
        // Total area invariant.
        let covered: u64 = m.iter().map(|(b, _)| b.area()).sum();
        assert_eq!(covered, m.extent().area());
        // Disjointness invariant.
        let frags: Vec<_> = m.iter().map(|(b, _)| *b).collect();
        for (i, a) in frags.iter().enumerate() {
            for b in &frags[i + 1..] {
                assert!(!a.intersects(b), "{a} intersects {b}");
            }
        }
        // Sort-order invariant of the interval index.
        for w in frags.windows(2) {
            assert!(w[0].min.0 < w[1].min.0, "entries out of order");
        }
    }

    #[test]
    fn map_remains_total_partition_under_random_updates() {
        let mut rng = XorShift64::new(77);
        let mut m = RegionMap::new(Range::d2(32, 32), 0u64);
        let rand_box = |rng: &mut XorShift64| {
            let x0 = rng.next_below(32);
            let y0 = rng.next_below(32);
            let x1 = x0 + rng.next_range(1, 16);
            let y1 = y0 + rng.next_range(1, 16);
            GridBox::d2((x0, y0), (x1, y1))
        };
        for step in 0..300 {
            match step % 3 {
                0 => m.update_box(&rand_box(&mut rng), step),
                1 => m.update_boxes([
                    (rand_box(&mut rng), step),
                    (rand_box(&mut rng), step + 1_000_000),
                ]),
                _ => m.apply_to_region(&Region::from(rand_box(&mut rng)), |v| {
                    v.wrapping_mul(31).wrapping_add(7)
                }),
            }
            check_invariants(&m);
        }
    }

    /// The pre-indexing seed implementation: flat vector, full rebuild and
    /// deep value clone on every update. Kept as the executable
    /// specification the indexed map is checked against.
    struct NaiveMap<T> {
        extent: GridBox,
        entries: Vec<(GridBox, T)>,
    }

    impl<T: Clone + PartialEq> NaiveMap<T> {
        fn new(extent: Range, default: T) -> Self {
            let full = GridBox::full(extent);
            NaiveMap { extent: full, entries: vec![(full, default)] }
        }

        fn update_box(&mut self, b: &GridBox, value: T) {
            let b = b.intersection(&self.extent);
            if b.is_empty() {
                return;
            }
            let mut next = Vec::new();
            for (eb, ev) in self.entries.drain(..) {
                if eb.intersects(&b) {
                    for rest in eb.difference(&b) {
                        next.push((rest, ev.clone()));
                    }
                } else {
                    next.push((eb, ev));
                }
            }
            next.push((b, value));
            self.entries = next;
        }

        fn apply_to_region(&mut self, region: &Region, f: impl Fn(&T) -> T) {
            let mut next = Vec::new();
            for (eb, ev) in self.entries.drain(..) {
                let inside = region.intersection_box(&eb);
                if inside.is_empty() {
                    next.push((eb, ev));
                    continue;
                }
                for ib in inside.boxes() {
                    next.push((*ib, f(&ev)));
                }
                for ob in Region::from(eb).difference(&inside).boxes() {
                    next.push((*ob, ev.clone()));
                }
            }
            self.entries = next;
        }

        fn at(&self, p: Point) -> Option<&T> {
            self.entries
                .iter()
                .find(|(b, _)| b.contains_point(p))
                .map(|(_, v)| v)
        }
    }

    /// Satellite property test: the indexed map stays value-equal to the
    /// naive reference (and a total partition of the extent) under ~10k
    /// random update / batched-update / apply / query operations.
    #[test]
    fn indexed_map_matches_naive_reference_under_random_ops() {
        const W: u64 = 24;
        let mut rng = XorShift64::new(0xDECAF);
        let mut idx = RegionMap::new(Range::d2(W, W), 0u64);
        let mut naive = NaiveMap::new(Range::d2(W, W), 0u64);
        let rand_box = |rng: &mut XorShift64| {
            let x0 = rng.next_below(W);
            let y0 = rng.next_below(W);
            let x1 = x0 + rng.next_range(1, 12);
            let y1 = y0 + rng.next_range(1, 12);
            GridBox::d2((x0, y0), (x1, y1))
        };
        for step in 0..10_000u64 {
            match rng.next_below(10) {
                0..=3 => {
                    let b = rand_box(&mut rng);
                    idx.update_box(&b, step);
                    naive.update_box(&b, step);
                }
                4..=6 => {
                    // Batched overwrite == sequential overwrites, in order.
                    let boxes = [rand_box(&mut rng), rand_box(&mut rng), rand_box(&mut rng)];
                    idx.update_boxes(boxes.iter().enumerate().map(|(i, b)| (*b, step + i as u64)));
                    for (i, b) in boxes.iter().enumerate() {
                        naive.update_box(b, step + i as u64);
                    }
                }
                7..=8 => {
                    let r = Region::from_boxes([rand_box(&mut rng), rand_box(&mut rng)]);
                    let f = |v: &u64| v.wrapping_mul(6364136223846793005).wrapping_add(step);
                    idx.apply_to_region(&r, f);
                    naive.apply_to_region(&r, f);
                }
                _ => {
                    // Query op: covering fragments of a random box agree in
                    // area and point values.
                    let b = rand_box(&mut rng).intersection(&idx.extent());
                    let q = idx.query_box(&b);
                    let covered: u64 = q.iter().map(|(qb, _)| qb.area()).sum();
                    assert_eq!(covered, b.area());
                    for (qb, qv) in &q {
                        assert_eq!(naive.at(qb.min), Some(qv), "at {}", qb.min);
                    }
                }
            }
            if step % 128 == 0 {
                check_invariants(&idx);
                for x in 0..W {
                    for y in 0..W {
                        let p = Point::d2(x, y);
                        assert_eq!(idx.at(p), naive.at(p), "mismatch at {p} after step {step}");
                    }
                }
            }
        }
        check_invariants(&idx);
        for x in 0..W {
            for y in 0..W {
                let p = Point::d2(x, y);
                assert_eq!(idx.at(p), naive.at(p), "final mismatch at {p}");
            }
        }
    }
}
