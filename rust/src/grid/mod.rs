//! n-dimensional index-space algebra.
//!
//! All dependency tracking in the three graph layers happens at the
//! granularity of *regions* of buffer index space (the paper tracks
//! "individual buffer elements ... with the help of range mappers", §2.3).
//! This module provides the value types for that:
//!
//! - [`Point`] / [`Range`] — positions and extents, canonically 3-dimensional
//!   (lower-dimensional spaces pad trailing extents with 1, like SYCL).
//! - [`GridBox`] — a half-open axis-aligned box `[min, max)`.
//! - [`Region`] — a finite union of disjoint boxes, kept normalized.
//! - [`RegionMap`] — a map from buffer space to values, used for
//!   original-producer and coherence tracking.

mod boxes;
mod point;
mod region;
mod region_map;

pub use boxes::GridBox;
pub use point::{Point, Range};
pub use region::Region;
pub use region_map::RegionMap;
