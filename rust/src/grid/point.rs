//! Points and ranges in the canonical 3-dimensional index space.

use std::fmt;
use std::ops::{Add, Index, IndexMut, Sub};

/// A position in 3-dimensional index space. Lower-dimensional spaces use
/// trailing zero coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Point(pub [u64; 3]);

/// An extent in 3-dimensional index space. Lower-dimensional spaces use
/// trailing extents of 1, mirroring SYCL's `range` semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Range(pub [u64; 3]);

impl Point {
    /// The origin `[0, 0, 0]`.
    pub const ZERO: Point = Point([0, 0, 0]);

    /// 1-dimensional point (trailing coordinates zero).
    pub fn d1(x: u64) -> Point {
        Point([x, 0, 0])
    }

    /// 2-dimensional point.
    pub fn d2(x: u64, y: u64) -> Point {
        Point([x, y, 0])
    }

    /// 3-dimensional point.
    pub fn d3(x: u64, y: u64, z: u64) -> Point {
        Point([x, y, z])
    }

    /// Component-wise minimum.
    pub fn min(self, o: Point) -> Point {
        Point([self.0[0].min(o.0[0]), self.0[1].min(o.0[1]), self.0[2].min(o.0[2])])
    }

    /// Component-wise maximum.
    pub fn max(self, o: Point) -> Point {
        Point([self.0[0].max(o.0[0]), self.0[1].max(o.0[1]), self.0[2].max(o.0[2])])
    }

    /// Component-wise saturating subtraction.
    pub fn saturating_sub(self, o: Point) -> Point {
        Point([
            self.0[0].saturating_sub(o.0[0]),
            self.0[1].saturating_sub(o.0[1]),
            self.0[2].saturating_sub(o.0[2]),
        ])
    }

    /// True if every coordinate of `self` is `<=` the matching coordinate.
    pub fn all_le(self, o: Point) -> bool {
        (0..3).all(|d| self.0[d] <= o.0[d])
    }

    /// True if every coordinate of `self` is `<` the matching coordinate.
    pub fn all_lt(self, o: Point) -> bool {
        (0..3).all(|d| self.0[d] < o.0[d])
    }
}

impl Range {
    /// The unit range `[1, 1, 1]` (a single element).
    pub const UNIT: Range = Range([1, 1, 1]);

    /// 1-dimensional range (trailing extents 1).
    pub fn d1(x: u64) -> Range {
        Range([x, 1, 1])
    }

    /// 2-dimensional range.
    pub fn d2(x: u64, y: u64) -> Range {
        Range([x, y, 1])
    }

    /// 3-dimensional range.
    pub fn d3(x: u64, y: u64, z: u64) -> Range {
        Range([x, y, z])
    }

    /// Total number of elements (product of extents).
    pub fn size(self) -> u64 {
        self.0[0] * self.0[1] * self.0[2]
    }

    /// True if any extent is zero.
    pub fn is_empty(self) -> bool {
        self.size() == 0
    }

    /// The effective dimensionality: index of the last extent `> 1`, plus 1.
    /// A unit range reports 1.
    pub fn dims(self) -> usize {
        if self.0[2] > 1 {
            3
        } else if self.0[1] > 1 {
            2
        } else {
            1
        }
    }
}

impl From<Range> for Point {
    fn from(r: Range) -> Point {
        Point(r.0)
    }
}

impl From<Point> for Range {
    fn from(p: Point) -> Range {
        Range(p.0)
    }
}

impl Index<usize> for Point {
    type Output = u64;
    fn index(&self, d: usize) -> &u64 {
        &self.0[d]
    }
}

impl IndexMut<usize> for Point {
    fn index_mut(&mut self, d: usize) -> &mut u64 {
        &mut self.0[d]
    }
}

impl Index<usize> for Range {
    type Output = u64;
    fn index(&self, d: usize) -> &u64 {
        &self.0[d]
    }
}

impl IndexMut<usize> for Range {
    fn index_mut(&mut self, d: usize) -> &mut u64 {
        &mut self.0[d]
    }
}

impl Add for Point {
    type Output = Point;
    fn add(self, o: Point) -> Point {
        Point([self.0[0] + o.0[0], self.0[1] + o.0[1], self.0[2] + o.0[2]])
    }
}

impl Sub for Point {
    type Output = Point;
    fn sub(self, o: Point) -> Point {
        Point([self.0[0] - o.0[0], self.0[1] - o.0[1], self.0[2] - o.0[2]])
    }
}

impl fmt::Display for Point {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}, {}, {}]", self.0[0], self.0[1], self.0[2])
    }
}

impl fmt::Display for Range {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{{}, {}, {}}}", self.0[0], self.0[1], self.0[2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_pad_canonically() {
        assert_eq!(Point::d1(5), Point([5, 0, 0]));
        assert_eq!(Point::d2(5, 6), Point([5, 6, 0]));
        assert_eq!(Range::d1(5), Range([5, 1, 1]));
        assert_eq!(Range::d2(5, 6), Range([5, 6, 1]));
    }

    #[test]
    fn range_size_and_dims() {
        assert_eq!(Range::d1(10).size(), 10);
        assert_eq!(Range::d3(2, 3, 4).size(), 24);
        assert_eq!(Range::d1(10).dims(), 1);
        assert_eq!(Range::d2(10, 2).dims(), 2);
        assert_eq!(Range::d3(1, 1, 2).dims(), 3);
        assert_eq!(Range::UNIT.dims(), 1);
        assert!(Range::d2(0, 5).is_empty());
    }

    #[test]
    fn point_lattice_ops() {
        let a = Point::d3(1, 5, 2);
        let b = Point::d3(3, 2, 2);
        assert_eq!(a.min(b), Point::d3(1, 2, 2));
        assert_eq!(a.max(b), Point::d3(3, 5, 2));
        assert!(Point::d3(1, 2, 2).all_le(a.max(b)));
        assert!(!a.all_lt(b));
    }

    #[test]
    fn point_arithmetic() {
        assert_eq!(Point::d2(1, 2) + Point::d2(3, 4), Point::d2(4, 6));
        assert_eq!(Point::d2(3, 4) - Point::d2(1, 2), Point::d2(2, 2));
        assert_eq!(Point::d1(1).saturating_sub(Point::d1(5)), Point::ZERO);
    }
}
