//! Low-overhead event tracing for the concurrent scheduler/executor.
//!
//! The paper's central claim — instruction-graph scheduling running
//! *concurrently* with execution — is only demonstrable with a timeline:
//! when was each instruction compiled, when was it issued, when did it
//! retire, and what was each lane doing meanwhile. This module records
//! exactly that, with a design constraint of near-zero cost when disabled
//! and no cross-thread contention when enabled:
//!
//! - A single global [`AtomicBool`] gates every record call. Disabled, a
//!   record is one relaxed load and a branch — cheap enough to leave
//!   compiled into the scheduler and executor hot paths (guarded by a
//!   `micro_scheduler` bench row).
//! - Enabled, events go into a plain `Vec` in thread-local storage; no
//!   locks, no allocation beyond the vec's amortized growth. Buffers are
//!   flushed into a global sink when each thread exits (all runtime
//!   threads are joined during shutdown) and on [`drain`].
//! - Timestamps are nanoseconds from a process-wide epoch fixed at
//!   [`enable`] time, so rows from different threads line up.
//!
//! Post-run, [`drain`] yields a [`Trace`] that exports to Chrome's
//! `chrome://tracing` JSON ([`chrome::to_chrome_json`]), to a Graphviz DAG
//! with critical-path annotation ([`dot::to_dot`]), and summarizes the
//! paper's concurrency claim as a [`SchedulerLag`] metric (how long each
//! instruction sat compiled-but-unissued, against how busy the lanes were).

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

pub mod chrome;
pub mod dot;

/// Global recording gate. Relaxed ordering is sufficient: a record racing
/// an enable/disable transition may be dropped or kept, both acceptable.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Process-wide time origin, fixed on first [`enable`].
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Merged event sink; thread-local buffers land here on thread exit.
static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());

/// Which timeline row an event belongs to, within one node's process row.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Track {
    /// The application thread driving the queue.
    Main,
    /// The scheduler thread (CDAG/IDAG compilation).
    Scheduler,
    /// The executor thread (admission, dispatch, retirement).
    Executor,
    /// Inbound comm activity observed by the executor's poll loop.
    CommIn,
    /// The outbound comm lane (send instructions).
    Comm,
    /// Kernel lane of one device.
    DeviceKernel(u64),
    /// Host-to-device copy lane of one device.
    DeviceCopyIn(u64),
    /// Device-to-host copy lane of one device.
    DeviceCopyOut(u64),
    /// One host task lane.
    Host(u64),
    /// Free-form row, used by the discrete-event simulator's converter.
    Named(String),
}

impl Track {
    /// Stable ordering rank for export (lower = higher in the timeline).
    fn rank(&self) -> u64 {
        match self {
            Track::Main => 0,
            Track::Scheduler => 1,
            Track::Executor => 2,
            Track::CommIn => 3,
            Track::Comm => 4,
            Track::Host(i) => 10 + i,
            Track::DeviceKernel(d) => 100 + 10 * d,
            Track::DeviceCopyIn(d) => 101 + 10 * d,
            Track::DeviceCopyOut(d) => 102 + 10 * d,
            Track::Named(_) => 1000,
        }
    }

    /// Human-readable row label.
    pub fn label(&self) -> String {
        match self {
            Track::Main => "main".into(),
            Track::Scheduler => "scheduler".into(),
            Track::Executor => "executor".into(),
            Track::CommIn => "comm-in".into(),
            Track::Comm => "comm lane".into(),
            Track::Host(i) => format!("host lane {i}"),
            Track::DeviceKernel(d) => format!("D{d} kernel"),
            Track::DeviceCopyIn(d) => format!("D{d} copy-in"),
            Track::DeviceCopyOut(d) => format!("D{d} copy-out"),
            Track::Named(s) => s.clone(),
        }
    }
}

/// What happened. Instants carry `start_ns == end_ns`; spans cover a range.
#[derive(Debug, Clone)]
pub enum EventKind {
    /// A task entered the scheduler queue (recorded on the main thread as
    /// the application submits).
    TaskSubmit { task: u64 },
    /// One scheduler wakeup: TDAG batch through CDAG + IDAG compilation.
    SchedBatch { tasks: u64, instructions: u64, queue_len: u64 },
    /// The lookahead window flushed (allocation-shape mismatch or horizon).
    LookaheadFlush,
    /// An instruction left the IDAG generator, dependencies resolved.
    Compiled { instr: u64, mnemonic: &'static str, deps: Vec<u64> },
    /// The executor dispatched the instruction to its lane/engine.
    Issue { instr: u64 },
    /// The instruction completed and released its dependents.
    Retire { instr: u64 },
    /// A lane actually ran the instruction's payload (kernel, copy, send,
    /// host task); recorded on the lane's own track.
    Exec { instr: u64, mnemonic: &'static str },
    /// An inbound payload arrived from a peer.
    DataIn { from: u64, bytes: u64 },
    /// An inbound pilot arrived from a peer.
    PilotIn { from: u64 },
    /// A liveness heartbeat arrived from a peer.
    HeartbeatIn { from: u64 },
    /// A transport fault report surfaced to the executor (CRC reject,
    /// sequence gap, oversized/truncated frame, or — `fatal` — peer loss).
    CommFault { from: u64, what: &'static str, fatal: bool },
    /// The transport re-established a broken stream to a peer.
    Reconnect { peer: u64 },
    /// The transport re-sent unacked frames to a peer.
    Retransmit { peer: u64 },
    /// The arena backed an alloc instruction.
    Alloc { bytes: u64 },
    /// Free-form span (simulator timelines).
    Span { label: String },
}

impl EventKind {
    /// Short display name (Chrome event name / dot node label).
    pub fn name(&self) -> &str {
        match self {
            EventKind::TaskSubmit { .. } => "task submit",
            EventKind::SchedBatch { .. } => "compile batch",
            EventKind::LookaheadFlush => "lookahead flush",
            EventKind::Compiled { .. } => "compiled",
            EventKind::Issue { .. } => "issue",
            EventKind::Retire { .. } => "retire",
            EventKind::Exec { mnemonic, .. } => mnemonic,
            EventKind::DataIn { .. } => "data in",
            EventKind::PilotIn { .. } => "pilot in",
            EventKind::HeartbeatIn { .. } => "heartbeat in",
            EventKind::CommFault { .. } => "fault",
            EventKind::Reconnect { .. } => "reconnect",
            EventKind::Retransmit { .. } => "retransmit",
            EventKind::Alloc { .. } => "alloc",
            EventKind::Span { label } => label,
        }
    }
}

/// One recorded event.
#[derive(Debug, Clone)]
pub struct Event {
    pub node: u64,
    pub track: Track,
    pub start_ns: u64,
    pub end_ns: u64,
    pub kind: EventKind,
}

impl Event {
    pub fn is_span(&self) -> bool {
        self.end_ns > self.start_ns
    }
}

/// Thread-local buffer whose drop (at thread exit, after the runtime joins
/// the thread) merges its events into the global sink.
struct LocalBuf {
    events: Vec<Event>,
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        if !self.events.is_empty() {
            if let Ok(mut sink) = SINK.lock() {
                sink.append(&mut self.events);
            }
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = const { RefCell::new(LocalBuf { events: Vec::new() }) };
}

/// Turn recording on. Fixes the time epoch on first call.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn recording off (already-buffered events stay until [`drain`]).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Whether recording is currently on. This is the hot-path guard: callers
/// that must build a payload (e.g. dependency vectors) check it first.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds since the trace epoch. Returns 0 if tracing never enabled.
#[inline]
pub fn now_ns() -> u64 {
    match EPOCH.get() {
        Some(e) => e.elapsed().as_nanos() as u64,
        None => 0,
    }
}

/// Record a fully-formed event (caller supplies timestamps).
#[inline]
pub fn record(ev: Event) {
    if !enabled() {
        return;
    }
    push(ev);
}

/// Record an instantaneous event stamped now.
#[inline]
pub fn instant(node: u64, track: Track, kind: EventKind) {
    if !enabled() {
        return;
    }
    let t = now_ns();
    push(Event { node, track, start_ns: t, end_ns: t, kind });
}

/// Record a span that started at `start_ns` (from [`now_ns`]) and ends now.
#[inline]
pub fn span(node: u64, track: Track, start_ns: u64, kind: EventKind) {
    if !enabled() {
        return;
    }
    let end = now_ns();
    push(Event { node, track, start_ns, end_ns: end.max(start_ns), kind });
}

fn push(ev: Event) {
    // Ignore records from threads whose TLS is mid-teardown.
    let _ = LOCAL.try_with(|b| b.borrow_mut().events.push(ev));
}

/// Flush the calling thread's buffer into the global sink.
pub fn flush_thread() {
    LOCAL.with(|b| {
        let mut b = b.borrow_mut();
        if !b.events.is_empty() {
            SINK.lock().expect("trace sink lock poisoned").append(&mut b.events);
        }
    });
}

/// Stop recording and take everything recorded so far. Only events from
/// threads that have exited (the runtime joins all of its threads during
/// shutdown) and from the calling thread are guaranteed to be included.
pub fn drain() -> Trace {
    disable();
    flush_thread();
    let events = std::mem::take(&mut *SINK.lock().expect("trace sink lock poisoned"));
    Trace { events }
}

/// A drained set of events plus the analyses the CLI and tests consume.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<Event>,
}

/// The `scheduler_lag` summary: quantifies §2's concurrent-scheduling
/// claim. For each instruction observed both leaving the scheduler
/// (`Compiled`) and entering a lane (`Issue`), the lag is the time it sat
/// compiled-but-unissued; lane-busy vs wall time shows whether the
/// executor was starved (high lag + idle lanes) or saturated (lag is free).
#[derive(Debug, Clone, Default)]
pub struct SchedulerLag {
    /// Instructions with both a `Compiled` and an `Issue` record.
    pub instructions: u64,
    /// Mean compiled→issued wait.
    pub mean_lag_ns: f64,
    /// Worst compiled→issued wait.
    pub max_lag_ns: u64,
    /// Total lane-execution time summed over all lanes and nodes.
    pub lane_busy_ns: u64,
    /// First-to-last event wall-clock extent.
    pub wall_ns: u64,
}

impl fmt::Display for SchedulerLag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "scheduler_lag: {} instructions, mean {:.1} us compiled->issued, \
             max {:.1} us; lanes busy {:.2} ms over {:.2} ms wall",
            self.instructions,
            self.mean_lag_ns / 1_000.0,
            self.max_lag_ns as f64 / 1_000.0,
            self.lane_busy_ns as f64 / 1e6,
            self.wall_ns as f64 / 1e6,
        )
    }
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Node ids present, ascending.
    pub fn nodes(&self) -> Vec<u64> {
        let mut ns: Vec<u64> = self.events.iter().map(|e| e.node).collect();
        ns.sort_unstable();
        ns.dedup();
        ns
    }

    /// Count of events matching a predicate.
    pub fn count(&self, pred: impl Fn(&EventKind) -> bool) -> usize {
        self.events.iter().filter(|e| pred(&e.kind)).count()
    }

    /// Schema self-check: spans must not end before they start, per-track
    /// event order must be chronological (each track is written by exactly
    /// one thread), and every `Retire` needs a preceding `Issue` for the
    /// same (node, instruction).
    pub fn validate(&self) -> Result<(), String> {
        let mut last: HashMap<(u64, &Track), u64> = HashMap::new();
        let mut issued: std::collections::HashSet<(u64, u64)> = Default::default();
        for ev in &self.events {
            if ev.end_ns < ev.start_ns {
                return Err(format!("event ends before it starts: {ev:?}"));
            }
            let key = (ev.node, &ev.track);
            if let Some(prev) = last.get(&key) {
                if ev.start_ns < *prev {
                    return Err(format!(
                        "track {:?} on node {} goes backwards in time at {ev:?}",
                        ev.track, ev.node
                    ));
                }
            }
            last.insert(key, ev.start_ns);
            match ev.kind {
                EventKind::Issue { instr } => {
                    issued.insert((ev.node, instr));
                }
                EventKind::Retire { instr } => {
                    if !issued.contains(&(ev.node, instr)) {
                        return Err(format!(
                            "node {} retired I{} without an issue record",
                            ev.node, instr
                        ));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Derive the [`SchedulerLag`] summary.
    pub fn scheduler_lag(&self) -> SchedulerLag {
        let mut compiled: HashMap<(u64, u64), u64> = HashMap::new();
        let mut lags: Vec<u64> = Vec::new();
        let mut lane_busy = 0u64;
        let mut t_min = u64::MAX;
        let mut t_max = 0u64;
        for ev in &self.events {
            t_min = t_min.min(ev.start_ns);
            t_max = t_max.max(ev.end_ns);
            match ev.kind {
                EventKind::Compiled { instr, .. } => {
                    compiled.insert((ev.node, instr), ev.start_ns);
                }
                EventKind::Issue { instr } => {
                    if let Some(c) = compiled.get(&(ev.node, instr)) {
                        lags.push(ev.start_ns.saturating_sub(*c));
                    }
                }
                EventKind::Exec { .. } | EventKind::Span { .. } => {
                    lane_busy += ev.end_ns - ev.start_ns;
                }
                _ => {}
            }
        }
        let n = lags.len() as u64;
        SchedulerLag {
            instructions: n,
            mean_lag_ns: if n == 0 {
                0.0
            } else {
                lags.iter().sum::<u64>() as f64 / n as f64
            },
            max_lag_ns: lags.iter().copied().max().unwrap_or(0),
            lane_busy_ns: lane_busy,
            wall_ns: if t_max >= t_min { t_max - t_min } else { 0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tracing state is process-global; serialize the tests that toggle it.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn ev(node: u64, track: Track, start: u64, end: u64, kind: EventKind) -> Event {
        Event { node, track, start_ns: start, end_ns: end, kind }
    }

    #[test]
    fn disabled_records_are_dropped() {
        let _g = TEST_LOCK.lock().unwrap();
        let _ = drain(); // clears the sink and disables recording
        instant(0, Track::Executor, EventKind::Issue { instr: 1 });
        assert_eq!(drain().len(), 0);
    }

    #[test]
    fn enabled_records_round_trip_through_drain() {
        let _g = TEST_LOCK.lock().unwrap();
        let _ = drain();
        enable();
        instant(0, Track::Executor, EventKind::Issue { instr: 7 });
        let t0 = now_ns();
        span(0, Track::DeviceKernel(0), t0, EventKind::Exec { instr: 7, mnemonic: "device kernel" });
        instant(0, Track::Executor, EventKind::Retire { instr: 7 });
        let tr = drain();
        assert_eq!(tr.len(), 3);
        assert!(tr.validate().is_ok());
        assert!(!enabled(), "drain must disable recording");
    }

    #[test]
    fn events_from_other_threads_are_flushed_on_join() {
        let _g = TEST_LOCK.lock().unwrap();
        let _ = drain();
        enable();
        let j = std::thread::spawn(|| {
            instant(3, Track::Scheduler, EventKind::LookaheadFlush);
        });
        j.join().unwrap();
        let tr = drain();
        assert_eq!(tr.count(|k| matches!(k, EventKind::LookaheadFlush)), 1);
        assert_eq!(tr.nodes(), vec![3]);
    }

    #[test]
    fn validate_rejects_retire_without_issue() {
        let tr = Trace {
            events: vec![ev(0, Track::Executor, 5, 5, EventKind::Retire { instr: 9 })],
        };
        assert!(tr.validate().is_err());
    }

    #[test]
    fn validate_rejects_backwards_track_time() {
        let tr = Trace {
            events: vec![
                ev(0, Track::Executor, 10, 10, EventKind::Issue { instr: 1 }),
                ev(0, Track::Executor, 5, 5, EventKind::Retire { instr: 1 }),
            ],
        };
        assert!(tr.validate().is_err());
    }

    #[test]
    fn scheduler_lag_pairs_compiled_with_issue() {
        let tr = Trace {
            events: vec![
                ev(
                    0,
                    Track::Scheduler,
                    100,
                    100,
                    EventKind::Compiled { instr: 1, mnemonic: "x", deps: vec![] },
                ),
                ev(0, Track::Executor, 400, 400, EventKind::Issue { instr: 1 }),
                ev(
                    0,
                    Track::DeviceKernel(0),
                    400,
                    900,
                    EventKind::Exec { instr: 1, mnemonic: "x" },
                ),
            ],
        };
        let lag = tr.scheduler_lag();
        assert_eq!(lag.instructions, 1);
        assert_eq!(lag.mean_lag_ns, 300.0);
        assert_eq!(lag.max_lag_ns, 300);
        assert_eq!(lag.lane_busy_ns, 500);
        assert_eq!(lag.wall_ns, 800);
    }
}
