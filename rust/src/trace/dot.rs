//! Graphviz export of the traced instruction DAG, with the critical path
//! highlighted.
//!
//! The graph is reconstructed entirely from the trace: `Compiled` events
//! carry each instruction's dependency edges, `Exec` spans (falling back
//! to issue→retire extent) supply weights. Instruction ids are node-local
//! and monotonically increasing with dependencies pointing backwards, so
//! the longest weighted path is a single forward scan in id order. Each
//! cluster node becomes a dot subgraph cluster with its own critical path
//! painted red.

use super::{EventKind, Trace};
use std::collections::HashMap;
use std::fmt::Write as _;

struct InstrInfo {
    mnemonic: &'static str,
    deps: Vec<u64>,
    dur_ns: u64,
}

/// Render the whole trace as a dot digraph (one cluster per node).
pub fn to_dot(trace: &Trace) -> String {
    let mut out = String::from("digraph idag {\n  rankdir=LR;\n  node [shape=box, fontsize=10];\n");
    for node in trace.nodes() {
        let instrs = collect(trace, node);
        if instrs.is_empty() {
            continue;
        }
        let critical = critical_path(&instrs);
        let _ = writeln!(out, "  subgraph cluster_n{node} {{");
        let _ = writeln!(out, "    label=\"node {node}\";");
        let mut ids: Vec<&u64> = instrs.keys().collect();
        ids.sort();
        for id in &ids {
            let info = &instrs[*id];
            let hot = critical.contains(*id);
            let _ = writeln!(
                out,
                "    n{node}_i{id} [label=\"I{id} {}\\n{:.1} us\"{}];",
                info.mnemonic,
                info.dur_ns as f64 / 1_000.0,
                if hot { ", color=red, penwidth=2" } else { "" }
            );
        }
        for id in &ids {
            for dep in &instrs[*id].deps {
                if !instrs.contains_key(dep) {
                    continue; // dependency compiled before tracing began
                }
                let hot = critical.contains(*id) && critical.contains(dep);
                let _ = writeln!(
                    out,
                    "    n{node}_i{dep} -> n{node}_i{id}{};",
                    if hot { " [color=red, penwidth=2]" } else { "" }
                );
            }
        }
        out.push_str("  }\n");
    }
    out.push_str("}\n");
    out
}

/// Gather per-instruction metadata and execution durations for one node.
fn collect(trace: &Trace, node: u64) -> HashMap<u64, InstrInfo> {
    let mut instrs: HashMap<u64, InstrInfo> = HashMap::new();
    let mut issue: HashMap<u64, u64> = HashMap::new();
    let mut extent: HashMap<u64, u64> = HashMap::new();
    for ev in trace.events.iter().filter(|e| e.node == node) {
        match &ev.kind {
            EventKind::Compiled { instr, mnemonic, deps } => {
                instrs.insert(
                    *instr,
                    InstrInfo { mnemonic, deps: deps.clone(), dur_ns: 0 },
                );
            }
            EventKind::Exec { instr, .. } => {
                instrs
                    .entry(*instr)
                    .and_modify(|i| i.dur_ns += ev.end_ns - ev.start_ns);
            }
            EventKind::Issue { instr } => {
                issue.insert(*instr, ev.start_ns);
            }
            EventKind::Retire { instr } => {
                if let Some(t0) = issue.get(instr) {
                    extent.insert(*instr, ev.end_ns.saturating_sub(*t0));
                }
            }
            _ => {}
        }
    }
    // Instructions without a lane span (inline, receives) get their
    // issue→retire extent as the weight.
    for (id, info) in instrs.iter_mut() {
        if info.dur_ns == 0 {
            info.dur_ns = extent.get(id).copied().unwrap_or(0);
        }
    }
    instrs
}

/// Longest weighted path through the dependency DAG (ids ascend along
/// edges, so a forward scan in id order is a topological order).
fn critical_path(instrs: &HashMap<u64, InstrInfo>) -> std::collections::HashSet<u64> {
    let mut ids: Vec<u64> = instrs.keys().copied().collect();
    ids.sort_unstable();
    let mut dist: HashMap<u64, u64> = HashMap::new();
    let mut parent: HashMap<u64, u64> = HashMap::new();
    let (mut best_id, mut best_dist) = (None, 0u64);
    for id in &ids {
        let info = &instrs[id];
        let mut d = 0u64;
        for dep in &info.deps {
            if let Some(dd) = dist.get(dep) {
                if *dd >= d {
                    d = *dd;
                    parent.insert(*id, *dep);
                }
            }
        }
        let total = d + info.dur_ns;
        dist.insert(*id, total);
        if total >= best_dist {
            best_dist = total;
            best_id = Some(*id);
        }
    }
    let mut path = std::collections::HashSet::new();
    let mut cur = best_id;
    while let Some(id) = cur {
        path.insert(id);
        cur = parent.get(&id).copied();
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Event, Track};

    fn compiled(node: u64, instr: u64, deps: Vec<u64>, ts: u64) -> Event {
        Event {
            node,
            track: Track::Scheduler,
            start_ns: ts,
            end_ns: ts,
            kind: EventKind::Compiled { instr, mnemonic: "device kernel", deps },
        }
    }

    fn exec(node: u64, instr: u64, start: u64, end: u64) -> Event {
        Event {
            node,
            track: Track::DeviceKernel(0),
            start_ns: start,
            end_ns: end,
            kind: EventKind::Exec { instr, mnemonic: "device kernel" },
        }
    }

    #[test]
    fn critical_path_prefers_heavier_chain() {
        // 1 -> 2 (10us) and 1 -> 3 (1us); 2,3 -> 4. Path 1-2-4 must win.
        let tr = Trace {
            events: vec![
                compiled(0, 1, vec![], 0),
                compiled(0, 2, vec![1], 1),
                compiled(0, 3, vec![1], 2),
                compiled(0, 4, vec![2, 3], 3),
                exec(0, 1, 10, 1_010),
                exec(0, 2, 1_010, 11_010),
                exec(0, 3, 1_010, 2_010),
                exec(0, 4, 11_010, 12_010),
            ],
        };
        let dot = to_dot(&tr);
        assert!(dot.contains("digraph idag"));
        assert!(dot.contains("subgraph cluster_n0"));
        assert!(dot.contains("n0_i2 [label=\"I2 device kernel\\n10.0 us\", color=red"));
        // The light branch stays uncolored.
        assert!(dot.contains("n0_i3 [label=\"I3 device kernel\\n1.0 us\"];"));
        assert!(dot.contains("n0_i1 -> n0_i2 [color=red"));
        assert!(dot.contains("n0_i3 -> n0_i4;"));
    }

    #[test]
    fn missing_dependencies_are_tolerated() {
        let tr = Trace {
            events: vec![compiled(0, 5, vec![2], 0)], // dep 2 never traced
        };
        let dot = to_dot(&tr);
        assert!(dot.contains("n0_i5"));
        assert!(!dot.contains("n0_i2 ->"));
    }
}
