//! Chrome `chrome://tracing` / Perfetto JSON export.
//!
//! Layout: one *process* row per cluster node (`pid` = node id), one
//! *thread* row per runtime track (`tid` from the track's stable rank).
//! Spans become `"ph":"X"` complete events, instants `"ph":"i"` with
//! thread scope. Timestamps are microseconds (the format's unit) with
//! nanosecond precision kept in the fraction. Events are emitted sorted by
//! timestamp so the file itself is monotonic — `scripts/check_trace.py`
//! and the CI schema self-test rely on that.

use super::{Event, EventKind, Trace, Track};
use crate::util::JobId;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Serialize a trace to a self-contained Chrome-tracing JSON document.
pub fn to_chrome_json(trace: &Trace) -> String {
    // Assign tids per (node, track), ordered by track rank then label so
    // numbering is deterministic across runs.
    let mut tracks: BTreeMap<(u64, u64, String), &Track> = BTreeMap::new();
    for ev in &trace.events {
        tracks
            .entry((ev.node, ev.track.rank(), ev.track.label()))
            .or_insert(&ev.track);
    }
    let mut tid_of: std::collections::HashMap<(u64, &Track), u64> = Default::default();
    let mut out = String::with_capacity(trace.events.len() * 96 + 1024);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    let mut push_obj = |out: &mut String, first: &mut bool, body: &str| {
        if !*first {
            out.push(',');
        }
        *first = false;
        out.push('{');
        out.push_str(body);
        out.push('}');
    };

    // Metadata: process (node) and thread (track) names.
    let mut nodes_named: std::collections::HashSet<u64> = Default::default();
    for (i, ((node, _rank, label), track)) in tracks.iter().enumerate() {
        let tid = i as u64;
        tid_of.insert((*node, *track), tid);
        if nodes_named.insert(*node) {
            push_obj(
                &mut out,
                &mut first,
                &format!(
                    "\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{node},\"tid\":0,\
                     \"args\":{{\"name\":\"node {node}\"}}"
                ),
            );
        }
        push_obj(
            &mut out,
            &mut first,
            &format!(
                "\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{node},\"tid\":{tid},\
                 \"args\":{{\"name\":\"{}\"}}",
                escape(label)
            ),
        );
    }

    let mut ordered: Vec<&Event> = trace.events.iter().collect();
    ordered.sort_by_key(|e| (e.start_ns, e.node));
    for ev in ordered {
        let tid = tid_of[&(ev.node, &ev.track)];
        let ts = ev.start_ns as f64 / 1_000.0;
        let mut body = format!(
            "\"name\":\"{}\",\"cat\":\"celerity\",\"pid\":{},\"tid\":{tid},\"ts\":{ts:.3}",
            escape(ev.kind.name()),
            ev.node
        );
        if ev.is_span() {
            let dur = (ev.end_ns - ev.start_ns) as f64 / 1_000.0;
            let _ = write!(body, ",\"ph\":\"X\",\"dur\":{dur:.3}");
        } else {
            body.push_str(",\"ph\":\"i\",\"s\":\"t\"");
        }
        let _ = write!(body, ",\"args\":{{{}}}", args_json(&ev.kind));
        push_obj(&mut out, &mut first, &body);
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

/// Per-kind `args` payload (already-valid JSON object body).
fn args_json(kind: &EventKind) -> String {
    match kind {
        EventKind::TaskSubmit { task } => format!("\"task\":{task}"),
        EventKind::SchedBatch { tasks, instructions, queue_len } => format!(
            "\"tasks\":{tasks},\"instructions\":{instructions},\"queue_len\":{queue_len}"
        ),
        EventKind::LookaheadFlush => String::new(),
        EventKind::Compiled { instr, deps, .. } => {
            // Full edge list, not just a count: `scripts/check_trace.py`
            // cross-checks executor completion order against these static
            // dependencies.
            let deps: Vec<String> = deps.iter().map(u64::to_string).collect();
            format!("{},\"deps\":[{}]", instr_args(*instr), deps.join(","))
        }
        EventKind::Issue { instr } | EventKind::Retire { instr } => instr_args(*instr),
        EventKind::Exec { instr, .. } => instr_args(*instr),
        EventKind::DataIn { from, bytes } => format!("\"from\":{from},\"bytes\":{bytes}"),
        EventKind::PilotIn { from } | EventKind::HeartbeatIn { from } => {
            format!("\"from\":{from}")
        }
        EventKind::CommFault { from, what, fatal } => {
            format!("\"from\":{from},\"what\":\"{}\",\"fatal\":{fatal}", escape(what))
        }
        EventKind::Reconnect { peer } | EventKind::Retransmit { peer } => {
            format!("\"peer\":{peer}")
        }
        EventKind::Alloc { bytes } => format!("\"bytes\":{bytes}"),
        EventKind::Span { .. } => String::new(),
    }
}

/// Instruction-keyed args, annotated with the owning job (decoded from the
/// id's high bits) on multi-tenant traces. Job 0 — the single-tenant
/// default — is omitted so existing traces serialize unchanged.
fn instr_args(instr: u64) -> String {
    let job = JobId::of(instr).0;
    if job == 0 {
        format!("\"instr\":{instr}")
    } else {
        format!("\"instr\":{instr},\"job\":{job}")
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            events: vec![
                Event {
                    node: 0,
                    track: Track::Scheduler,
                    start_ns: 1_000,
                    end_ns: 4_500,
                    kind: EventKind::SchedBatch { tasks: 1, instructions: 3, queue_len: 0 },
                },
                Event {
                    node: 0,
                    track: Track::Executor,
                    start_ns: 5_000,
                    end_ns: 5_000,
                    kind: EventKind::Issue { instr: 2 },
                },
                Event {
                    node: 1,
                    track: Track::DeviceKernel(0),
                    start_ns: 6_000,
                    end_ns: 9_000,
                    kind: EventKind::Exec { instr: 2, mnemonic: "device kernel" },
                },
            ],
        }
    }

    #[test]
    fn emits_parseable_monotonic_document() {
        let json = to_chrome_json(&sample());
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("\"displayTimeUnit\":\"ms\"}"));
        // Both process rows named, all three events present.
        assert!(json.contains("\"name\":\"node 0\""));
        assert!(json.contains("\"name\":\"node 1\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("\"dur\":3.500"));
        // Balanced braces — cheap well-formedness proxy without a parser.
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn annotates_multi_tenant_instructions_with_their_job() {
        let base = JobId(3).base();
        let t = Trace {
            events: vec![
                Event {
                    node: 0,
                    track: Track::Executor,
                    start_ns: 0,
                    end_ns: 0,
                    kind: EventKind::Issue { instr: base + 7 },
                },
                Event {
                    node: 0,
                    track: Track::Executor,
                    start_ns: 1,
                    end_ns: 1,
                    kind: EventKind::Issue { instr: 7 },
                },
            ],
        };
        let json = to_chrome_json(&t);
        assert!(json.contains(&format!("\"instr\":{},\"job\":3", base + 7)), "{json}");
        // Job 0 stays unannotated: single-tenant traces are unchanged.
        assert!(json.contains("\"args\":{\"instr\":7}"), "{json}");
    }

    #[test]
    fn escapes_quotes_in_labels() {
        let t = Trace {
            events: vec![Event {
                node: 0,
                track: Track::Named("a\"b".into()),
                start_ns: 0,
                end_ns: 1,
                kind: EventKind::Span { label: "x\"y".into() },
            }],
        };
        let json = to_chrome_json(&t);
        assert!(json.contains("a\\\"b"));
        assert!(json.contains("x\\\"y"));
    }
}
