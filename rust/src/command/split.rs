//! Static work assignment: splitting kernel index spaces.
//!
//! CDAG generation "distributes work between cluster nodes by statically
//! splitting the task kernel index space along one or more axes" (§3.1);
//! instruction-graph generation "applies the above scheme a second time" to
//! distribute the node's command chunk between its local devices.

use crate::grid::{GridBox, Range};

/// Along which axes a kernel index space is split. User-controllable via
/// the hint API (the paper's `hint`/`constraint` mechanism, §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SplitHint {
    /// Split along axis 0 into contiguous slabs (default).
    #[default]
    D1,
    /// Split along axes 0 and 1 into a near-square grid of tiles.
    D2,
}

/// Split `range` into (up to) `parts` non-empty contiguous chunks along
/// axis `axis`. Remainder elements are distributed to the leading chunks, so
/// chunk sizes differ by at most one slab. Returns fewer than `parts` chunks
/// when the axis extent is smaller than `parts`.
pub fn split_axis(range: &GridBox, parts: u64, axis: usize) -> Vec<GridBox> {
    assert!(parts > 0);
    let extent = range.max[axis] - range.min[axis];
    let parts = parts.min(extent.max(1));
    let base = extent / parts;
    let rem = extent % parts;
    let mut out = Vec::with_capacity(parts as usize);
    let mut lo = range.min[axis];
    for i in 0..parts {
        let len = base + u64::from(i < rem);
        if len == 0 {
            continue;
        }
        let mut chunk = *range;
        chunk.min[axis] = lo;
        chunk.max[axis] = lo + len;
        lo += len;
        out.push(chunk);
    }
    out
}

/// Split `range` into (up to) `parts` chunks according to `hint`.
///
/// The 2D split factors `parts` into a near-square `rows × cols` grid
/// (falling back to 1D when the space is 1-dimensional).
pub fn split_range(range: Range, parts: u64, hint: SplitHint) -> Vec<GridBox> {
    let full = GridBox::full(range);
    if full.is_empty() {
        return Vec::new();
    }
    match hint {
        SplitHint::D1 => split_axis(&full, parts, 0),
        SplitHint::D2 => {
            if range.dims() < 2 {
                return split_axis(&full, parts, 0);
            }
            // Near-square factorization: rows = largest divisor <= sqrt.
            let mut rows = (parts as f64).sqrt() as u64;
            while rows > 1 && parts % rows != 0 {
                rows -= 1;
            }
            let cols = parts / rows.max(1);
            let mut out = Vec::new();
            for row in split_axis(&full, rows.max(1), 0) {
                out.extend(split_axis(&row, cols, 1));
            }
            out
        }
    }
}

/// Split an arbitrary box (not necessarily origin-anchored) into (up to)
/// `parts` chunks according to `hint`. This is the second, device-level
/// split of hierarchical work assignment (§3.1): the node's command chunk is
/// subdivided between its local devices.
pub fn split_box(b: &GridBox, parts: u64, hint: SplitHint) -> Vec<GridBox> {
    if b.is_empty() {
        return Vec::new();
    }
    match hint {
        SplitHint::D1 => split_axis(b, parts, 0),
        SplitHint::D2 => {
            if b.range().dims() < 2 {
                return split_axis(b, parts, 0);
            }
            let mut rows = (parts as f64).sqrt() as u64;
            while rows > 1 && parts % rows != 0 {
                rows -= 1;
            }
            let cols = parts / rows.max(1);
            let mut out = Vec::new();
            for row in split_axis(b, rows.max(1), 0) {
                out.extend(split_axis(&row, cols, 1));
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::Region;

    #[test]
    fn split_1d_even() {
        let chunks = split_range(Range::d1(100), 4, SplitHint::D1);
        assert_eq!(chunks.len(), 4);
        assert!(chunks.iter().all(|c| c.area() == 25));
        assert_eq!(Region::from_boxes(chunks), Region::full(Range::d1(100)));
    }

    #[test]
    fn split_1d_remainder_leading_chunks_bigger() {
        let chunks = split_range(Range::d1(10), 3, SplitHint::D1);
        let sizes: Vec<u64> = chunks.iter().map(|c| c.area()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn split_more_parts_than_elements() {
        let chunks = split_range(Range::d1(3), 8, SplitHint::D1);
        assert_eq!(chunks.len(), 3);
        assert!(chunks.iter().all(|c| c.area() == 1));
    }

    #[test]
    fn split_2d_tiles_cover_exactly() {
        let r = Range::d2(64, 64);
        let chunks = split_range(r, 4, SplitHint::D2);
        assert_eq!(chunks.len(), 4);
        assert_eq!(Region::from_boxes(chunks.clone()), Region::full(r));
        // Near-square: each tile is 32x32.
        assert!(chunks.iter().all(|c| c.range() == Range::d2(32, 32)));
    }

    #[test]
    fn split_2d_on_1d_space_falls_back() {
        let chunks = split_range(Range::d1(64), 4, SplitHint::D2);
        assert_eq!(chunks.len(), 4);
        assert!(chunks.iter().all(|c| c.range() == Range::d1(16)));
    }

    #[test]
    fn split_2d_nonsquare_count() {
        let chunks = split_range(Range::d2(60, 60), 6, SplitHint::D2);
        assert_eq!(chunks.len(), 6); // 2 x 3 grid
        assert_eq!(Region::from_boxes(chunks), Region::full(Range::d2(60, 60)));
    }

    #[test]
    fn chunks_are_disjoint_property() {
        use crate::util::XorShift64;
        let mut rng = XorShift64::new(5);
        for _ in 0..100 {
            let r = Range::d2(rng.next_range(1, 100), rng.next_range(1, 100));
            let parts = rng.next_range(1, 16);
            let hint = if rng.chance(0.5) { SplitHint::D1 } else { SplitHint::D2 };
            let chunks = split_range(r, parts, hint);
            assert!(!chunks.is_empty());
            for (i, a) in chunks.iter().enumerate() {
                for b in &chunks[i + 1..] {
                    assert!(!a.intersects(b), "{a} vs {b}");
                }
            }
            assert_eq!(Region::from_boxes(chunks), Region::full(r));
        }
    }
}
