//! The command layer: per-node CDAG generation (§2.4, §3.4).
//!
//! From the (globally identical) task graph, every node generates *only its
//! own* slice of the command graph — the distributed-generation property
//! that keeps Celerity scheduling scalable [19]. Commands distribute the
//! task kernel index space onto nodes and model the peer-to-peer
//! communication necessary to satisfy the resulting data dependencies:
//! *push* commands carry receiver and precise region; *await-push* commands
//! only know the union of inbound subregions (§3.4).

mod split;

pub use split::{split_axis, split_box, split_range, SplitHint};

use crate::buffer::BufferPool;
use crate::dag::{Dag, Dep, DepKind};
use crate::grid::{GridBox, Region, RegionMap};
use crate::task::{EpochAction, TaskKind, TaskRef};
use crate::util::{BufferId, CommandId, JobId, NodeId, TaskId};
use std::collections::HashMap;
use std::sync::Arc;

/// A set of cluster nodes, as a bitmask. The tracking structures store one
/// of these per buffer fragment; 64 nodes × 4 GPUs covers the paper's
/// 128-GPU experiments twice over.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct NodeSet(pub u64);

impl NodeSet {
    pub const EMPTY: NodeSet = NodeSet(0);

    pub fn all(num_nodes: u64) -> NodeSet {
        assert!(num_nodes <= 64, "NodeSet supports up to 64 nodes");
        if num_nodes == 64 {
            NodeSet(u64::MAX)
        } else {
            NodeSet((1u64 << num_nodes) - 1)
        }
    }

    pub fn single(n: NodeId) -> NodeSet {
        NodeSet(1u64 << n.0)
    }

    pub fn contains(self, n: NodeId) -> bool {
        self.0 & (1u64 << n.0) != 0
    }

    pub fn insert(self, n: NodeId) -> NodeSet {
        NodeSet(self.0 | (1u64 << n.0))
    }

    pub fn iter(self) -> impl Iterator<Item = NodeId> {
        (0..64).filter(move |i| self.0 & (1u64 << i) != 0).map(NodeId)
    }
}

/// Which collective pattern a [`CommandKind::Collective`] implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveKind {
    /// Every node owns a disjoint slice and every node needs the full
    /// region (N-body's position broadcast): n·(n−1) p2p pushes collapse
    /// into n−1 ring rounds.
    AllGather,
    /// One node owns the entire region and every node needs it: the ring
    /// degenerates into a pipeline rooted at the owner.
    Broadcast,
}

impl CollectiveKind {
    pub fn name(self) -> &'static str {
        match self {
            CollectiveKind::AllGather => "all-gather",
            CollectiveKind::Broadcast => "broadcast",
        }
    }
}

/// What a command does. One node's view: execution of its kernel chunk plus
/// the communication that chunk requires.
#[derive(Debug, Clone)]
pub enum CommandKind {
    /// Execute this node's chunk of the task kernel index space.
    Execute { chunk: GridBox },
    /// Send `region` of `buffer` to node `target` (MPI_Isend at the
    /// instruction level). Precise by construction (§3.4).
    Push { buffer: BufferId, region: Region, target: NodeId },
    /// Await inbound transfers covering `region` of `buffer`. Senders and
    /// per-sender geometry are *unknown* until pilot messages arrive (§3.4).
    AwaitPush { buffer: BufferId, region: Region },
    /// Group communication detected from the CDAG geometry: `region` of
    /// `buffer` is gathered so every node ends up with all of it. Replaces
    /// this node's n−1 pushes *and* its await-push with one command;
    /// `slices[i]` is the slice node *i* contributes (empty for
    /// non-owners). Executed as a ring schedule over the ordinary
    /// pilot/send primitives (n−1 rounds), so no transport changes are
    /// needed. Emitted only when the exact pattern check passes — every
    /// other geometry falls back to p2p push/await-push.
    Collective {
        buffer: BufferId,
        region: Region,
        kind: CollectiveKind,
        slices: Arc<Vec<GridBox>>,
    },
    /// Scheduling-complexity bound (§3.5).
    Horizon,
    /// Graph-based synchronization with the main thread.
    Epoch(EpochAction),
}

/// One node of the per-node command graph.
#[derive(Debug, Clone)]
pub struct Command {
    pub id: CommandId,
    /// The task this command implements (execute) or serves (push/await).
    pub task: TaskRef,
    pub kind: CommandKind,
    pub deps: Vec<(CommandId, DepKind)>,
}

impl Command {
    pub fn is_execution(&self) -> bool {
        matches!(self.kind, CommandKind::Execute { .. })
    }

    /// Short display label ("C5 push B0→N1" style).
    pub fn label(&self) -> String {
        match &self.kind {
            CommandKind::Execute { chunk } => {
                format!("{} exec '{}' {}", self.id, self.task.name, chunk)
            }
            CommandKind::Push { buffer, target, region } => {
                format!("{} push {buffer}→{target} {region}", self.id)
            }
            CommandKind::AwaitPush { buffer, region } => {
                format!("{} await {buffer} {region}", self.id)
            }
            CommandKind::Collective { buffer, region, kind, .. } => {
                format!("{} {} {buffer} {region}", self.id, kind.name())
            }
            CommandKind::Horizon => format!("{} horizon", self.id),
            CommandKind::Epoch(a) => format!("{} epoch {a:?}", self.id),
        }
    }
}

pub type CommandRef = Arc<Command>;

/// A correctness error detected during command generation (§4.4).
#[derive(Debug, Clone)]
pub enum CommandError {
    /// Two concurrent chunks of a split task write overlapping regions;
    /// coherence tracking would become ambiguous.
    OverlappingWrites {
        task: TaskId,
        buffer: BufferId,
        overlap: Region,
    },
}

impl std::fmt::Display for CommandError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommandError::OverlappingWrites { task, buffer, overlap } => write!(
                f,
                "task {task}: concurrent chunks write overlapping region {overlap} of {buffer}"
            ),
        }
    }
}

/// Per-buffer distributed tracking state. *All* nodes compute identical
/// copies of this state by replaying the same deterministic algorithm over
/// the same TDAG — that is what lets each node generate only its own
/// commands without any coordination.
struct BufferState {
    /// Which node produced the newest version of each element.
    owner: RegionMap<NodeId>,
    /// Which nodes hold a current replica of each element.
    replicated: RegionMap<NodeSet>,
    /// Local command-level last producer (execute or await-push) — local
    /// dependencies only.
    last_writer_cmd: RegionMap<Option<CommandId>>,
    /// Local commands reading each element since its last local write.
    readers_since: RegionMap<Vec<CommandId>>,
}

/// Generates this node's slice of the command graph from the task stream.
pub struct CdagGenerator {
    node: NodeId,
    num_nodes: u64,
    hint: SplitHint,
    buffers: BufferPool,
    states: HashMap<BufferId, BufferState>,
    dag: Dag<CommandRef>,
    outbox: Vec<CommandRef>,
    errors: Vec<CommandError>,
    current_horizon: Option<CommandId>,
    last_epoch: Option<CommandId>,
    /// Lower detected all-gather/broadcast patterns to
    /// [`CommandKind::Collective`] instead of p2p pairs. On by default;
    /// turned off for the p2p-identity tests and the bench ablation.
    collectives: bool,
    /// Statistics: collective commands emitted (ablation metric).
    pub collectives_emitted: u64,
}

impl CdagGenerator {
    pub fn new(node: NodeId, num_nodes: u64, hint: SplitHint, buffers: BufferPool) -> Self {
        Self::with_job(JobId(0), node, num_nodes, hint, buffers)
    }

    /// Generator whose command ids live in `job`'s namespace; all per-job
    /// generators on one node share the CDAG layer without id collisions.
    pub fn with_job(
        job: JobId,
        node: NodeId,
        num_nodes: u64,
        hint: SplitHint,
        buffers: BufferPool,
    ) -> Self {
        assert!(node.0 < num_nodes);
        CdagGenerator {
            node,
            num_nodes,
            hint,
            buffers,
            states: HashMap::new(),
            dag: Dag::with_base(job.base()),
            outbox: Vec::new(),
            errors: Vec::new(),
            current_horizon: None,
            last_epoch: None,
            collectives: true,
            collectives_emitted: 0,
        }
    }

    /// Enable or disable collective-group lowering (default: enabled).
    pub fn set_collectives(&mut self, enabled: bool) {
        self.collectives = enabled;
    }

    /// Register a buffer created after generator construction (streaming
    /// creation in the live runtime; the pool snapshot is replaced wholesale
    /// since `BufferPool` is append-only and cheap to clone).
    pub fn notify_buffers(&mut self, pool: BufferPool) {
        self.buffers = pool;
    }

    fn ensure_state(&mut self, info: &crate::buffer::BufferInfo) {
        self.states.entry(info.id).or_insert_with(|| BufferState {
            owner: RegionMap::new(info.range, NodeId(0)),
            replicated: RegionMap::new(info.range, NodeSet::all(self.num_nodes)),
            last_writer_cmd: RegionMap::new(info.range, None),
            readers_since: RegionMap::new(info.range, Vec::new()),
        });
    }

    /// Process one task; appends this node's commands to the outbox.
    pub fn compile(&mut self, task: &TaskRef) {
        match &task.kind {
            TaskKind::DeviceCompute { range, accesses, .. }
            | TaskKind::HostTask { range, accesses, .. } => {
                self.compile_compute(task, *range, accesses.clone());
            }
            TaskKind::Horizon => {
                let id = self.push_front_command(task, CommandKind::Horizon);
                // Apply the previous horizon (subsume older local producers).
                if let Some(prev) = self.current_horizon.take() {
                    self.apply_boundary(prev);
                }
                self.current_horizon = Some(id);
            }
            TaskKind::Epoch(a) => {
                let id = self.push_front_command(task, CommandKind::Epoch(*a));
                self.apply_boundary(id);
                self.current_horizon = None;
                self.last_epoch = Some(id);
            }
        }
    }

    /// Drain commands generated since the last call.
    pub fn take_new_commands(&mut self) -> Vec<CommandRef> {
        std::mem::take(&mut self.outbox)
    }

    /// Drain detected correctness errors (§4.4).
    pub fn take_errors(&mut self) -> Vec<CommandError> {
        std::mem::take(&mut self.errors)
    }

    pub fn dag(&self) -> &Dag<CommandRef> {
        &self.dag
    }

    /// Render the local CDAG slice as Graphviz dot.
    pub fn to_dot(&self) -> String {
        self.dag.to_dot(&format!("cdag_{}", self.node), |c| c.label())
    }

    /// The chunks the given kernel range splits into, one per node (empty
    /// boxes for surplus nodes when the range is too small).
    pub fn node_chunks(&self, range: crate::grid::Range) -> Vec<GridBox> {
        let mut chunks = split_range(range, self.num_nodes, self.hint);
        chunks.resize(self.num_nodes as usize, GridBox::EMPTY);
        chunks
    }

    fn compile_compute(
        &mut self,
        task: &TaskRef,
        range: crate::grid::Range,
        accesses: Vec<crate::task::Access>,
    ) {
        for a in &accesses {
            let info = self.buffers.get(a.buffer).clone();
            self.ensure_state(&info);
        }
        let chunks = self.node_chunks(range);
        let my_chunk = chunks[self.node.0 as usize];

        // §4.4 overlapping-write detection across *all* chunks.
        for a in &accesses {
            if !a.mode.is_producer() {
                continue;
            }
            let info = self.buffers.get(a.buffer);
            let regions: Vec<Region> = chunks
                .iter()
                .map(|c| a.mapper.apply(c, range, info.range))
                .collect();
            for i in 0..regions.len() {
                for j in (i + 1)..regions.len() {
                    let overlap = regions[i].intersection(&regions[j]);
                    if !overlap.is_empty() {
                        log::error!(
                            "task {} '{}': chunks {i} and {j} write overlapping region {overlap} of buffer {}",
                            task.id, task.name, info.name
                        );
                        self.errors.push(CommandError::OverlappingWrites {
                            task: task.id,
                            buffer: a.buffer,
                            overlap,
                        });
                    }
                }
            }
        }

        // 0. Collective detection (ROADMAP "collective groups"): when every
        //    chunk consumes the *same* region of a buffer whose elements are
        //    each held exclusively by their owner, the p2p lowering would
        //    emit n−1 pushes + 1 await-push on every node — O(n²) transfers
        //    cluster-wide. Lower the whole exchange to one Collective
        //    command per node instead; anything that fails the pattern
        //    check keeps the precise p2p path.
        let mut collective_bufs: std::collections::HashSet<BufferId> =
            std::collections::HashSet::new();
        if self.collectives && self.num_nodes >= 2 {
            for a in &accesses {
                if !a.mode.is_consumer() || a.mode.is_producer() {
                    continue;
                }
                // Exactly one consumer access of this buffer in the task: a
                // second access could consume a different region and break
                // the geometry argument below.
                if accesses
                    .iter()
                    .filter(|b| b.buffer == a.buffer && b.mode.is_consumer())
                    .count()
                    != 1
                {
                    continue;
                }
                let info = self.buffers.get(a.buffer).clone();
                let Some((region, slices, kind)) =
                    self.detect_collective(a, &chunks, range, info.range)
                else {
                    continue;
                };
                let buffer = a.buffer;
                let own = Region::from(slices[self.node.0 as usize]);
                let inbound = region.difference(&own);
                // Dependencies mirror the p2p pair this replaces: dataflow
                // on the producers of our contribution (push semantics),
                // anti-dependencies against local commands still touching
                // the bytes the inbound slices overwrite (await semantics).
                let mut deps: Vec<(CommandId, DepKind)> = Vec::new();
                {
                    let st = &self.states[&buffer];
                    st.last_writer_cmd.for_each_in_region(&own, |_, w| {
                        if let Some(w) = w {
                            push_dep(&mut deps, *w, DepKind::Dataflow);
                        }
                    });
                    st.readers_since.for_each_in_region(&inbound, |_, readers| {
                        for r in readers {
                            push_dep(&mut deps, *r, DepKind::Anti);
                        }
                    });
                    st.last_writer_cmd.for_each_in_region(&inbound, |_, w| {
                        if let Some(w) = w {
                            push_dep(&mut deps, *w, DepKind::Anti);
                        }
                    });
                }
                let id = self.push_command(
                    task,
                    CommandKind::Collective {
                        buffer,
                        region: region.clone(),
                        kind,
                        slices: Arc::new(slices),
                    },
                    deps,
                );
                self.collectives_emitted += 1;
                // Local tracking: the collective produces the inbound bytes
                // (await-push role) and reads our owned slice (push role).
                let st = self.states.get_mut(&buffer).expect("buffer tracked since creation");
                if !inbound.is_empty() {
                    st.last_writer_cmd.update_region(&inbound, Some(id));
                    st.readers_since.update_region(&inbound, Vec::new());
                }
                if !own.is_empty() {
                    st.readers_since.apply_to_region(&own, |rs| {
                        let mut rs = rs.clone();
                        rs.push(id);
                        rs
                    });
                }
                collective_bufs.insert(buffer);
            }
        }

        // 1. Inbound: regions my chunk consumes that are neither produced
        //    here nor already replicated here → one await-push per buffer.
        let mut await_cmds: HashMap<BufferId, CommandId> = HashMap::new();
        for a in &accesses {
            if !a.mode.is_consumer() || collective_bufs.contains(&a.buffer) {
                continue;
            }
            let info = self.buffers.get(a.buffer).clone();
            let read = a.mapper.apply(&my_chunk, range, info.range);
            if read.is_empty() {
                continue;
            }
            let st = &self.states[&a.buffer];
            let mut missing_boxes: Vec<GridBox> = Vec::new();
            st.replicated.for_each_in_region(&read, |b, set| {
                if !set.contains(self.node) {
                    missing_boxes.push(b);
                }
            });
            let missing = Region::from_boxes(missing_boxes);
            if missing.is_empty() {
                continue;
            }
            // Anti-dependencies: the incoming data overwrites stale local
            // bytes; all local commands that touched them must be done.
            let mut deps: Vec<(CommandId, DepKind)> = Vec::new();
            {
                let st = &self.states[&a.buffer];
                st.readers_since.for_each_in_region(&missing, |_, readers| {
                    for r in readers {
                        push_dep(&mut deps, *r, DepKind::Anti);
                    }
                });
                st.last_writer_cmd.for_each_in_region(&missing, |_, w| {
                    if let Some(w) = w {
                        push_dep(&mut deps, *w, DepKind::Anti);
                    }
                });
            }
            let id = self.push_command(
                task,
                CommandKind::AwaitPush { buffer: a.buffer, region: missing.clone() },
                deps,
            );
            await_cmds.insert(a.buffer, id);
            // The await-push becomes the local original producer (§3.3).
            let st = self.states.get_mut(&a.buffer).expect("buffer tracked since creation");
            st.last_writer_cmd.update_region(&missing, Some(id));
            st.readers_since.update_region(&missing, Vec::new());
        }

        // 2. Outbound: regions peer chunks consume that *we* own and the
        //    peer does not replicate → one push per (buffer, peer).
        for a in &accesses {
            if !a.mode.is_consumer() || collective_bufs.contains(&a.buffer) {
                continue;
            }
            let info = self.buffers.get(a.buffer).clone();
            for (peer_idx, peer_chunk) in chunks.iter().enumerate() {
                let peer = NodeId(peer_idx as u64);
                if peer == self.node || peer_chunk.is_empty() {
                    continue;
                }
                let read = a.mapper.apply(peer_chunk, range, info.range);
                if read.is_empty() {
                    continue;
                }
                let st = &self.states[&a.buffer];
                // What we own out of the peer's need...
                let mut our_boxes: Vec<GridBox> = Vec::new();
                st.owner.for_each_in_region(&read, |b, o| {
                    if *o == self.node {
                        our_boxes.push(b);
                    }
                });
                let ours = Region::from_boxes(our_boxes);
                // ...minus what the peer already has.
                let mut send_boxes: Vec<GridBox> = Vec::new();
                st.replicated.for_each_in_region(&ours, |b, set| {
                    if !set.contains(peer) {
                        send_boxes.push(b);
                    }
                });
                let to_send = Region::from_boxes(send_boxes);
                if to_send.is_empty() {
                    continue;
                }
                let mut deps: Vec<(CommandId, DepKind)> = Vec::new();
                self.states[&a.buffer].last_writer_cmd.for_each_in_region(&to_send, |_, w| {
                    if let Some(w) = w {
                        push_dep(&mut deps, *w, DepKind::Dataflow);
                    }
                });
                let id = self.push_command(
                    task,
                    CommandKind::Push { buffer: a.buffer, region: to_send.clone(), target: peer },
                    deps,
                );
                // The push reads the region: record for anti-deps.
                let st = self.states.get_mut(&a.buffer).expect("buffer tracked since creation");
                st.readers_since.apply_to_region(&to_send, |rs| {
                    let mut rs = rs.clone();
                    rs.push(id);
                    rs
                });
            }
        }

        // 3. The execution command for our chunk.
        if !my_chunk.is_empty() {
            let mut deps: Vec<(CommandId, DepKind)> = Vec::new();
            for a in &accesses {
                let info = self.buffers.get(a.buffer).clone();
                let region = a.mapper.apply(&my_chunk, range, info.range);
                if region.is_empty() {
                    continue;
                }
                let st = &self.states[&a.buffer];
                if a.mode.is_consumer() {
                    st.last_writer_cmd.for_each_in_region(&region, |_, w| {
                        if let Some(w) = w {
                            push_dep(&mut deps, *w, DepKind::Dataflow);
                        }
                    });
                }
                if a.mode.is_producer() {
                    st.readers_since.for_each_in_region(&region, |_, readers| {
                        for r in readers {
                            push_dep(&mut deps, *r, DepKind::Anti);
                        }
                    });
                    st.last_writer_cmd.for_each_in_region(&region, |_, w| {
                        if let Some(w) = w {
                            push_dep(&mut deps, *w, DepKind::Output);
                        }
                    });
                }
            }
            if deps.is_empty() {
                if let Some(e) = self.last_epoch {
                    push_dep(&mut deps, e, DepKind::Sync);
                }
            }
            let id = self.push_command(task, CommandKind::Execute { chunk: my_chunk }, deps);
            // Local tracking updates for our own accesses.
            for a in &accesses {
                let info = self.buffers.get(a.buffer).clone();
                let region = a.mapper.apply(&my_chunk, range, info.range);
                let st = self.states.get_mut(&a.buffer).expect("buffer tracked since creation");
                if a.mode.is_producer() {
                    st.last_writer_cmd.update_region(&region, Some(id));
                    st.readers_since.update_region(&region, Vec::new());
                } else {
                    st.readers_since.apply_to_region(&region, |rs| {
                        let mut rs = rs.clone();
                        rs.push(id);
                        rs
                    });
                }
            }
        }

        // 4. Global (deterministically replicated) tracking updates.
        for a in &accesses {
            let info = self.buffers.get(a.buffer).clone();
            // Consumers replicate data onto every reading node.
            if a.mode.is_consumer() {
                for (idx, chunk) in chunks.iter().enumerate() {
                    let reader = NodeId(idx as u64);
                    let read = a.mapper.apply(chunk, range, info.range);
                    if read.is_empty() {
                        continue;
                    }
                    let st = self.states.get_mut(&a.buffer).expect("buffer tracked since creation");
                    st.replicated.apply_to_region(&read, |s| s.insert(reader));
                }
            }
            // Producers take exclusive ownership of written regions.
            if a.mode.is_producer() {
                for (idx, chunk) in chunks.iter().enumerate() {
                    let writer = NodeId(idx as u64);
                    let written = a.mapper.apply(chunk, range, info.range);
                    if written.is_empty() {
                        continue;
                    }
                    let st = self.states.get_mut(&a.buffer).expect("buffer tracked since creation");
                    st.owner.update_region(&written, writer);
                    st.replicated.update_region(&written, NodeSet::single(writer));
                }
            }
        }
    }

    /// Check one consumer access against the collective-group geometry:
    /// every chunk consumes the identical non-empty region, and every
    /// element of that region is replicated *only* on its owner, whose
    /// slice coalesces to a single box (the ring forwards one rectangle
    /// per round). Returns the gathered region, the per-node contribution
    /// slices (indexed by node id, `EMPTY` for non-owners) and the
    /// collective kind; `None` means the pattern does not apply and the
    /// caller keeps the p2p lowering.
    ///
    /// The check reads only the deterministically-replicated tracking
    /// state, so all nodes reach the same verdict without coordination —
    /// the same property that makes distributed p2p generation work.
    fn detect_collective(
        &self,
        a: &crate::task::Access,
        chunks: &[GridBox],
        range: crate::grid::Range,
        buffer_range: crate::grid::Range,
    ) -> Option<(Region, Vec<GridBox>, CollectiveKind)> {
        let region = a.mapper.apply(&chunks[0], range, buffer_range);
        if region.is_empty() {
            return None;
        }
        for c in &chunks[1..] {
            if a.mapper.apply(c, range, buffer_range) != region {
                return None;
            }
        }
        let st = &self.states[&a.buffer];
        let mut owner_boxes: Vec<Vec<GridBox>> = vec![Vec::new(); self.num_nodes as usize];
        let mut in_range = true;
        st.owner.for_each_in_region(&region, |b, o| {
            match owner_boxes.get_mut(o.0 as usize) {
                Some(v) => v.push(b),
                None => in_range = false,
            }
        });
        if !in_range {
            return None;
        }
        let mut slices = vec![GridBox::EMPTY; self.num_nodes as usize];
        let mut owners = 0u64;
        for (i, boxes) in owner_boxes.into_iter().enumerate() {
            if boxes.is_empty() {
                continue;
            }
            let owned = Region::from_boxes(boxes);
            if owned.boxes().len() != 1 {
                return None;
            }
            let mut exclusive = true;
            st.replicated.for_each_in_region(&owned, |_, set| {
                if *set != NodeSet::single(NodeId(i as u64)) {
                    exclusive = false;
                }
            });
            if !exclusive {
                return None;
            }
            slices[i] = owned.boxes()[0];
            owners += 1;
        }
        let kind = if owners == 1 {
            CollectiveKind::Broadcast
        } else {
            CollectiveKind::AllGather
        };
        Some((region, slices, kind))
    }

    /// Command depending on the entire local execution front (horizon/epoch).
    fn push_front_command(&mut self, task: &TaskRef, kind: CommandKind) -> CommandId {
        let deps: Vec<(CommandId, DepKind)> = self
            .dag
            .front()
            .into_iter()
            .map(|id| (CommandId(id), DepKind::Sync))
            .collect();
        self.push_command(task, kind, deps)
    }

    /// Substitute `boundary` for every older producer/reader and prune.
    fn apply_boundary(&mut self, boundary: CommandId) {
        for st in self.states.values_mut() {
            let full = Region::full(st.last_writer_cmd.extent().range());
            st.last_writer_cmd.apply_to_region(&full, |w| match w {
                Some(w) if w.0 < boundary.0 => Some(boundary),
                other => *other,
            });
            st.readers_since.apply_to_region(&full, |rs| {
                let newer: Vec<CommandId> =
                    rs.iter().copied().filter(|r| r.0 >= boundary.0).collect();
                if rs.is_empty() {
                    Vec::new()
                } else if newer.len() == rs.len() {
                    rs.clone()
                } else {
                    let mut v = vec![boundary];
                    v.extend(newer);
                    v
                }
            });
        }
        self.dag.prune_before(boundary.0);
    }

    fn push_command(
        &mut self,
        task: &TaskRef,
        kind: CommandKind,
        deps: Vec<(CommandId, DepKind)>,
    ) -> CommandId {
        let id = CommandId(self.dag.total_created());
        let cmd = Arc::new(Command { id, task: task.clone(), kind, deps: deps.clone() });
        self.dag.push(
            cmd.clone(),
            deps.iter().map(|(d, k)| Dep { from: d.0, kind: *k }),
        );
        self.outbox.push(cmd);
        id
    }
}

fn push_dep(deps: &mut Vec<(CommandId, DepKind)>, id: CommandId, kind: DepKind) {
    if !deps.iter().any(|(d, _)| *d == id) {
        deps.push((id, kind));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::Range;
    use crate::task::{RangeMapper, TaskDecl, TaskManager};

    /// Build the N-body TDAG on a fresh manager and compile it on `nodes`
    /// CDAG generators; returns per-node command lists. `collectives`
    /// selects the lowering for the all-gather pattern (the p2p tests pin
    /// the paper's original push/await-push structure).
    fn compile_nbody_with(nodes: u64, steps: usize, collectives: bool) -> Vec<Vec<CommandRef>> {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(4096);
        let p = tm.create_buffer::<[f64; 3]>("P", n, true).id();
        let v = tm.create_buffer::<[f64; 3]>("V", n, true).id();
        for _ in 0..steps {
            tm.submit(
                TaskDecl::device("timestep", n)
                    .read(p, RangeMapper::All)
                    .read_write(v, RangeMapper::OneToOne),
            );
            tm.submit(
                TaskDecl::device("update", n)
                    .read(v, RangeMapper::OneToOne)
                    .read_write(p, RangeMapper::OneToOne),
            );
        }
        let tasks = tm.take_new_tasks();
        (0..nodes)
            .map(|nid| {
                let mut gen = CdagGenerator::new(
                    NodeId(nid),
                    nodes,
                    SplitHint::D1,
                    tm.buffers().clone(),
                );
                gen.set_collectives(collectives);
                for t in &tasks {
                    gen.compile(t);
                }
                assert!(gen.dag().check_acyclic());
                gen.take_new_commands()
            })
            .collect()
    }

    fn compile_nbody(nodes: u64, steps: usize) -> Vec<Vec<CommandRef>> {
        compile_nbody_with(nodes, steps, false)
    }

    #[test]
    fn single_node_generates_no_communication() {
        let cmds = compile_nbody(1, 2);
        assert!(cmds[0].iter().all(|c| !matches!(
            c.kind,
            CommandKind::Push { .. } | CommandKind::AwaitPush { .. }
        )));
        // 1 epoch + 4 executes
        assert_eq!(cmds[0].len(), 5);
    }

    #[test]
    fn two_nodes_reproduce_fig2_structure() {
        // Fig 2, node N0 of 2: first timestep needs no comm (data fully
        // replicated); the second timestep's all-read requires an await of
        // the peer half of P, and a push of our half.
        let per_node = compile_nbody(2, 2);
        let n0 = &per_node[0];

        let pushes: Vec<_> = n0
            .iter()
            .filter(|c| matches!(c.kind, CommandKind::Push { .. }))
            .collect();
        let awaits: Vec<_> = n0
            .iter()
            .filter(|c| matches!(c.kind, CommandKind::AwaitPush { .. }))
            .collect();
        assert_eq!(pushes.len(), 1, "{:#?}", n0.iter().map(|c| c.label()).collect::<Vec<_>>());
        assert_eq!(awaits.len(), 1);

        // The push sends our (lower) half of P to N1.
        match &pushes[0].kind {
            CommandKind::Push { buffer, region, target } => {
                assert_eq!(*buffer, BufferId(0));
                assert_eq!(*target, NodeId(1));
                assert_eq!(*region, Region::from(GridBox::d1(0, 2048)));
            }
            _ => unreachable!(),
        }
        // The await receives the peer (upper) half of P.
        match &awaits[0].kind {
            CommandKind::AwaitPush { buffer, region } => {
                assert_eq!(*buffer, BufferId(0));
                assert_eq!(*region, Region::from(GridBox::d1(2048, 4096)));
            }
            _ => unreachable!(),
        }

        // The push depends (dataflow) on the "update" execute that produced
        // our half of P.
        let update_exec = n0
            .iter()
            .find(|c| c.is_execution() && c.task.name == "update")
            .unwrap();
        assert!(pushes[0].deps.iter().any(|(d, k)| *d == update_exec.id && *k == DepKind::Dataflow));

        // The second timestep execute depends on the await-push.
        let second_timestep = n0
            .iter()
            .filter(|c| c.is_execution() && c.task.name == "timestep")
            .nth(1)
            .unwrap();
        assert!(second_timestep
            .deps
            .iter()
            .any(|(d, k)| *d == awaits[0].id && *k == DepKind::Dataflow));
    }

    #[test]
    fn communication_volume_symmetric_across_nodes() {
        let per_node = compile_nbody(4, 3);
        // Every node pushes its quarter of P to each of 3 peers per step
        // (after the first), and awaits the 3 remaining quarters.
        for cmds in &per_node {
            let push_bytes: u64 = cmds
                .iter()
                .filter_map(|c| match &c.kind {
                    CommandKind::Push { region, .. } => Some(region.area()),
                    _ => None,
                })
                .sum();
            let await_bytes: u64 = cmds
                .iter()
                .filter_map(|c| match &c.kind {
                    CommandKind::AwaitPush { region, .. } => Some(region.area()),
                    _ => None,
                })
                .sum();
            // 2 comm rounds (steps 2 and 3): push own 1024 elems ×3 peers,
            // await 3×1024 elems.
            assert_eq!(push_bytes, 2 * 3 * 1024);
            assert_eq!(await_bytes, 2 * 3 * 1024);
        }
    }

    #[test]
    fn no_push_for_already_replicated_data() {
        // Reading the same remote data twice must transfer it only once.
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(128);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        tm.submit(TaskDecl::device("w", n).read_write(b, RangeMapper::OneToOne));
        let o1 = tm.create_buffer::<f64>("O1", n, false).id();
        let o2 = tm.create_buffer::<f64>("O2", n, false).id();
        tm.submit(
            TaskDecl::device("r1", n)
                .read(b, RangeMapper::All)
                .write(o1, RangeMapper::OneToOne),
        );
        tm.submit(
            TaskDecl::device("r2", n)
                .read(b, RangeMapper::All)
                .write(o2, RangeMapper::OneToOne),
        );
        let tasks = tm.take_new_tasks();
        let mut gen = CdagGenerator::new(NodeId(0), 2, SplitHint::D1, tm.buffers().clone());
        gen.set_collectives(false);
        for t in &tasks {
            gen.compile(t);
        }
        let cmds = gen.take_new_commands();
        let pushes = cmds
            .iter()
            .filter(|c| matches!(c.kind, CommandKind::Push { .. }))
            .count();
        let awaits = cmds
            .iter()
            .filter(|c| matches!(c.kind, CommandKind::AwaitPush { .. }))
            .count();
        assert_eq!(pushes, 1, "second all-read must reuse the replica");
        assert_eq!(awaits, 1);
    }

    // ── collective-group lowering ───────────────────────────────────────

    fn count_kinds(cmds: &[CommandRef]) -> (usize, usize, usize) {
        let pushes = cmds.iter().filter(|c| matches!(c.kind, CommandKind::Push { .. })).count();
        let awaits =
            cmds.iter().filter(|c| matches!(c.kind, CommandKind::AwaitPush { .. })).count();
        let colls =
            cmds.iter().filter(|c| matches!(c.kind, CommandKind::Collective { .. })).count();
        (pushes, awaits, colls)
    }

    /// Acceptance criterion: nbody at 4 nodes compiles to O(n) collective
    /// rounds — one command per node per comm step — instead of the
    /// n·(n−1) push/await-push pairs of the p2p lowering.
    #[test]
    fn nbody_four_nodes_collective_command_counts() {
        let steps = 3; // comm happens on steps 2 and 3 → 2 exchanges
        let p2p = compile_nbody_with(4, steps, false);
        let coll = compile_nbody_with(4, steps, true);
        let mut p2p_pushes_total = 0;
        for (node, cmds) in p2p.iter().enumerate() {
            let (pushes, awaits, colls) = count_kinds(cmds);
            assert_eq!(pushes, 2 * 3, "node {node}: (n−1) pushes per exchange");
            assert_eq!(awaits, 2, "node {node}: 1 await-push per exchange");
            assert_eq!(colls, 0);
            p2p_pushes_total += pushes;
        }
        // Cluster-wide: n·(n−1) pushes per exchange — the O(n²) pattern.
        assert_eq!(p2p_pushes_total, 2 * 4 * 3);
        for (node, cmds) in coll.iter().enumerate() {
            let (pushes, awaits, colls) = count_kinds(cmds);
            assert_eq!((pushes, awaits), (0, 0), "node {node}: no p2p left for P");
            assert_eq!(colls, 2, "node {node}: one collective per exchange");
            for c in cmds {
                if let CommandKind::Collective { region, kind, slices, .. } = &c.kind {
                    assert_eq!(*kind, CollectiveKind::AllGather);
                    assert_eq!(*region, Region::from(GridBox::d1(0, 4096)));
                    assert_eq!(slices.len(), 4);
                    for (i, s) in slices.iter().enumerate() {
                        assert_eq!(
                            *s,
                            GridBox::d1(i as u64 * 1024, (i as u64 + 1) * 1024),
                            "slice of node {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn collective_depends_on_producer_and_feeds_consumer() {
        let per_node = compile_nbody_with(2, 2, true);
        let n0 = &per_node[0];
        let coll = n0
            .iter()
            .find(|c| matches!(c.kind, CommandKind::Collective { .. }))
            .expect("one collective on node 0");
        // Dataflow on the "update" execute that produced our half of P.
        let update_exec = n0
            .iter()
            .find(|c| c.is_execution() && c.task.name == "update")
            .unwrap();
        assert!(coll
            .deps
            .iter()
            .any(|(d, k)| *d == update_exec.id && *k == DepKind::Dataflow));
        // The second timestep execute consumes the gathered region.
        let second_timestep = n0
            .iter()
            .filter(|c| c.is_execution() && c.task.name == "timestep")
            .nth(1)
            .unwrap();
        assert!(second_timestep
            .deps
            .iter()
            .any(|(d, k)| *d == coll.id && *k == DepKind::Dataflow));
    }

    /// The detector must not fire on stencil halo exchanges (per-chunk
    /// read regions differ) — those stay on the precise p2p path.
    #[test]
    fn stencil_keeps_p2p_lowering_with_collectives_enabled() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d2(64, 64);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        let b = tm.create_buffer::<f64>("B", n, true).id();
        tm.submit(
            TaskDecl::device("s1", n)
                .read(a, RangeMapper::Neighborhood(Range::d2(1, 1)))
                .write(b, RangeMapper::OneToOne),
        );
        tm.submit(
            TaskDecl::device("s2", n)
                .read(b, RangeMapper::Neighborhood(Range::d2(1, 1)))
                .write(a, RangeMapper::OneToOne),
        );
        let tasks = tm.take_new_tasks();
        let mut gen = CdagGenerator::new(NodeId(0), 2, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            gen.compile(t);
        }
        let cmds = gen.take_new_commands();
        let (pushes, awaits, colls) = count_kinds(&cmds);
        assert_eq!(colls, 0, "halo exchange is not an all-gather");
        assert_eq!((pushes, awaits), (1, 1));
        assert_eq!(gen.collectives_emitted, 0);
    }

    /// Broadcast variant: one node owns the whole region, everyone reads it.
    #[test]
    fn single_owner_all_read_lowers_to_broadcast() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(256);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        let o = tm.create_buffer::<f64>("O", n, false).id();
        // A 1-item task: only node 0's chunk is non-empty → node 0 writes
        // (and thus owns) the whole fixed region.
        tm.submit(
            TaskDecl::device("root_write", Range::d1(1))
                .write(b, RangeMapper::Fixed(Region::full(n))),
        );
        tm.submit(
            TaskDecl::device("consume", n)
                .read(b, RangeMapper::All)
                .write(o, RangeMapper::OneToOne),
        );
        let tasks = tm.take_new_tasks();
        for nid in 0..2 {
            let mut gen =
                CdagGenerator::new(NodeId(nid), 2, SplitHint::D1, tm.buffers().clone());
            for t in &tasks {
                gen.compile(t);
            }
            let cmds = gen.take_new_commands();
            let colls: Vec<_> = cmds
                .iter()
                .filter_map(|c| match &c.kind {
                    CommandKind::Collective { kind, slices, .. } => Some((*kind, slices.clone())),
                    _ => None,
                })
                .collect();
            assert_eq!(colls.len(), 1, "node {nid}");
            let (kind, slices) = &colls[0];
            assert_eq!(*kind, CollectiveKind::Broadcast);
            assert_eq!(slices[0], GridBox::d1(0, 256));
            assert_eq!(slices[1], GridBox::EMPTY);
        }
    }

    /// Multi-box owner slices must be rejected: a partial rewrite
    /// fragments ownership so a node's slice no longer coalesces to one
    /// rectangle, and the ring protocol forwards exactly one rectangle per
    /// round — the all-read has to stay on the precise p2p path. A
    /// genuine all-gather in the same program still fires (control).
    #[test]
    fn multi_box_owner_slice_keeps_p2p_lowering() {
        for nodes in [2u64, 4] {
            let mut tm = TaskManager::with_horizon_step(u64::MAX);
            let n = Range::d1(64);
            let b = tm.create_buffer::<f64>("B", n, false).id();
            let o = tm.create_buffer::<f64>("O", n, false).id();
            let o2 = tm.create_buffer::<f64>("O2", n, false).id();
            tm.submit(TaskDecl::device("iota", n).write(b, RangeMapper::OneToOne));
            // Redistribute the prefix [0, 16): every node except node 0
            // now owns a shard of the prefix *plus* the rest of its
            // original slice — two disjoint boxes.
            tm.submit(
                TaskDecl::device("rewrite", Range::d1(16)).write(b, RangeMapper::OneToOne),
            );
            tm.submit(
                TaskDecl::device("consume", n)
                    .read(b, RangeMapper::All)
                    .write(o, RangeMapper::OneToOne),
            );
            // Control: O has exclusive single-box owners, so this all-read
            // is the genuine gather geometry.
            tm.submit(
                TaskDecl::device("consume2", n)
                    .read(o, RangeMapper::All)
                    .write(o2, RangeMapper::OneToOne),
            );
            let tasks = tm.take_new_tasks();
            for nid in 0..nodes {
                let mut gen =
                    CdagGenerator::new(NodeId(nid), nodes, SplitHint::D1, tm.buffers().clone());
                for t in &tasks {
                    gen.compile(t);
                }
                let cmds = gen.take_new_commands();
                assert!(gen.dag().check_acyclic());
                assert_eq!(
                    gen.collectives_emitted, 1,
                    "{nodes} nodes, node {nid}: only the control may lower collectively"
                );
                let colls: Vec<_> = cmds
                    .iter()
                    .filter(|c| matches!(c.kind, CommandKind::Collective { .. }))
                    .collect();
                assert_eq!(colls.len(), 1);
                assert_eq!(colls[0].task.name, "consume2", "node {nid}");
                // The fragmented gather fell back to pushes/await-pushes
                // for B.
                let b_awaits = cmds
                    .iter()
                    .filter(|c| {
                        matches!(&c.kind, CommandKind::AwaitPush { buffer, .. } if *buffer == b)
                    })
                    .count();
                assert!(b_awaits >= 1, "{nodes} nodes, node {nid}: p2p fallback must gather B");
            }
        }
    }

    /// Partial replication must be rejected: after a halo read, boundary
    /// elements live on two nodes, so a later all-read is no longer the
    /// exclusive-owner gather the ring forwards — p2p (which skips
    /// already-replicated bytes) is the only correct lowering.
    #[test]
    fn partially_replicated_buffer_keeps_p2p_lowering() {
        for nodes in [2u64, 4] {
            let mut tm = TaskManager::with_horizon_step(u64::MAX);
            let n = Range::d1(64);
            let b = tm.create_buffer::<f64>("B", n, false).id();
            let h = tm.create_buffer::<f64>("H", n, false).id();
            let o = tm.create_buffer::<f64>("O", n, false).id();
            tm.submit(TaskDecl::device("iota", n).write(b, RangeMapper::OneToOne));
            // The halo read replicates B's chunk-boundary elements onto the
            // neighbouring node as well as the owner.
            tm.submit(
                TaskDecl::device("halo", n)
                    .read(b, RangeMapper::Neighborhood(Range::d1(1)))
                    .write(h, RangeMapper::OneToOne),
            );
            tm.submit(
                TaskDecl::device("consume", n)
                    .read(b, RangeMapper::All)
                    .write(o, RangeMapper::OneToOne),
            );
            let tasks = tm.take_new_tasks();
            for nid in 0..nodes {
                let mut gen =
                    CdagGenerator::new(NodeId(nid), nodes, SplitHint::D1, tm.buffers().clone());
                for t in &tasks {
                    gen.compile(t);
                }
                let cmds = gen.take_new_commands();
                assert!(gen.dag().check_acyclic());
                assert_eq!(
                    gen.collectives_emitted, 0,
                    "{nodes} nodes, node {nid}: partially replicated all-read must stay p2p"
                );
                let (pushes, awaits, colls) = count_kinds(&cmds);
                assert_eq!(colls, 0);
                assert!(
                    pushes >= 1 && awaits >= 1,
                    "{nodes} nodes, node {nid}: p2p fallback must still communicate"
                );
            }
        }
    }

    /// Property test: on randomized programs (random buffer sizes, node
    /// counts, write extents and read mappers), whenever the detector fires
    /// on a node it must fire identically on *every* node, and the
    /// collective must carry exactly the communication the p2p lowering
    /// would have performed: inbound = the node's await-push region,
    /// contribution = what it would have pushed to each consuming peer. A
    /// detector firing on a non-all-gather geometry fails these checks.
    #[test]
    fn property_collective_matches_p2p_communication() {
        for seed in 1..=120u64 {
            let mut rng = crate::util::XorShift64::new(seed);
            let nodes = rng.next_range(2, 5);
            let len = rng.next_range(2, 8) * nodes; // splittable sizes
            let n = Range::d1(len);
            let mut tm = TaskManager::with_horizon_step(u64::MAX);
            let b = tm.create_buffer::<f64>("B", n, rng.chance(0.5)).id();
            let tasks = {
                for _ in 0..rng.next_range(1, 4) {
                    // Random producer: full or partial one-to-one write.
                    if rng.chance(0.7) {
                        tm.submit(TaskDecl::device("w", n).read_write(b, RangeMapper::OneToOne));
                    } else {
                        let sub = rng.next_range(1, len);
                        tm.submit(TaskDecl::device("wp", Range::d1(sub)).write(
                            b,
                            RangeMapper::Shift(crate::grid::Point::d1(
                                rng.next_below(len - sub + 1),
                            )),
                        ));
                    }
                    // Random consumer geometry.
                    let mapper = match rng.next_below(4) {
                        0 => RangeMapper::All,
                        1 => RangeMapper::OneToOne,
                        2 => {
                            let lo = rng.next_below(len);
                            let hi = rng.next_range(lo + 1, len);
                            RangeMapper::Fixed(Region::from(GridBox::d1(lo, hi)))
                        }
                        _ => RangeMapper::Neighborhood(Range::d1(rng.next_range(1, 3))),
                    };
                    tm.submit(TaskDecl::device("r", n).read(b, mapper));
                }
                tm.take_new_tasks()
            };

            // Compile every node twice: collectives on and off, in
            // lockstep, comparing the communication they describe.
            let mut fired_per_task: Vec<Vec<(u64, Region, Vec<GridBox>)>> = Vec::new();
            for nid in 0..nodes {
                let mut with = CdagGenerator::new(
                    NodeId(nid),
                    nodes,
                    SplitHint::D1,
                    tm.buffers().clone(),
                );
                let mut without = CdagGenerator::new(
                    NodeId(nid),
                    nodes,
                    SplitHint::D1,
                    tm.buffers().clone(),
                );
                without.set_collectives(false);
                let mut fired: Vec<(u64, Region, Vec<GridBox>)> = Vec::new();
                for (ti, t) in tasks.iter().enumerate() {
                    with.compile(t);
                    without.compile(t);
                    let wc = with.take_new_commands();
                    let pc = without.take_new_commands();
                    let colls: Vec<_> = wc
                        .iter()
                        .filter_map(|c| match &c.kind {
                            CommandKind::Collective { region, slices, .. } => {
                                Some((region.clone(), slices.as_ref().clone()))
                            }
                            _ => None,
                        })
                        .collect();
                    assert!(colls.len() <= 1, "seed {seed}: one buffer, one collective");
                    if let Some((region, slices)) = colls.into_iter().next() {
                        // Inbound must equal the p2p await-push region.
                        let own = Region::from(slices[nid as usize]);
                        let inbound = region.difference(&own);
                        let p2p_await = pc
                            .iter()
                            .filter_map(|c| match &c.kind {
                                CommandKind::AwaitPush { region, .. } => Some(region.clone()),
                                _ => None,
                            })
                            .fold(Region::empty(), |acc, r| acc.union(&r));
                        assert_eq!(
                            inbound, p2p_await,
                            "seed {seed} node {nid} task {ti}: collective inbound vs p2p awaits"
                        );
                        // Contribution must equal what we would have pushed
                        // to every consuming peer.
                        let mut push_regions: HashMap<NodeId, Region> = HashMap::new();
                        for c in &pc {
                            if let CommandKind::Push { region, target, .. } = &c.kind {
                                let e = push_regions
                                    .entry(*target)
                                    .or_insert_with(Region::empty);
                                *e = e.union(region);
                            }
                        }
                        for (peer, pushed) in &push_regions {
                            assert_eq!(
                                *pushed, own,
                                "seed {seed} node {nid} task {ti}: push to {peer} vs own slice"
                            );
                        }
                        if own.is_empty() {
                            assert!(push_regions.is_empty(), "seed {seed}: non-owner pushing");
                        } else {
                            assert_eq!(
                                push_regions.len() as u64,
                                nodes - 1,
                                "seed {seed} node {nid} task {ti}: all-gather pushes to every peer"
                            );
                        }
                        fired.push((ti as u64, region, slices));
                    } else {
                        // No collective → the p2p run compiled the same
                        // command kinds and geometry. (Ids and deps may
                        // differ once an earlier task lowered collectively,
                        // so compare id-free kind signatures.)
                        assert_eq!(
                            wc.iter().map(|c| format!("{:?}", c.kind)).collect::<Vec<_>>(),
                            pc.iter().map(|c| format!("{:?}", c.kind)).collect::<Vec<_>>(),
                            "seed {seed} node {nid} task {ti}: lowering must only differ when it fires"
                        );
                    }
                }
                assert!(with.dag().check_acyclic(), "seed {seed} node {nid}");
                fired_per_task.push(fired);
            }
            // Deterministic replication: every node fired on the same
            // tasks with the same geometry.
            for nid in 1..nodes as usize {
                assert_eq!(
                    fired_per_task[0], fired_per_task[nid],
                    "seed {seed}: node {nid} disagrees with node 0 on collective geometry"
                );
            }
        }
    }

    #[test]
    fn stencil_exchanges_only_halo() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d2(64, 64);
        let a = tm.create_buffer::<f64>("A", n, true).id();
        let b = tm.create_buffer::<f64>("B", n, true).id();
        // Two stencil steps: B <- stencil(A), A <- stencil(B).
        tm.submit(
            TaskDecl::device("s1", n)
                .read(a, RangeMapper::Neighborhood(Range::d2(1, 1)))
                .write(b, RangeMapper::OneToOne),
        );
        tm.submit(
            TaskDecl::device("s2", n)
                .read(b, RangeMapper::Neighborhood(Range::d2(1, 1)))
                .write(a, RangeMapper::OneToOne),
        );
        let tasks = tm.take_new_tasks();
        let mut gen = CdagGenerator::new(NodeId(0), 2, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            gen.compile(t);
        }
        let cmds = gen.take_new_commands();
        // s1 requires no comm (A replicated). s2 requires the halo row of B
        // produced by N1: rows [32, 33) — one row of 64 elements.
        let awaits: Vec<_> = cmds
            .iter()
            .filter_map(|c| match &c.kind {
                CommandKind::AwaitPush { buffer, region } => Some((*buffer, region.clone())),
                _ => None,
            })
            .collect();
        assert_eq!(awaits.len(), 1);
        assert_eq!(awaits[0].0, b);
        assert_eq!(awaits[0].1, Region::from(GridBox::d2((32, 0), (33, 64))));
        let pushes: Vec<_> = cmds
            .iter()
            .filter_map(|c| match &c.kind {
                CommandKind::Push { region, .. } => Some(region.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(pushes.len(), 1);
        assert_eq!(pushes[0], Region::from(GridBox::d2((31, 0), (32, 64))));
    }

    #[test]
    fn overlapping_write_detected() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(64);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        // Writing with an All mapper from a split task is a §4.4 error.
        tm.submit(TaskDecl::device("bad", n).write(b, RangeMapper::All));
        let tasks = tm.take_new_tasks();
        let mut gen = CdagGenerator::new(NodeId(0), 2, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            gen.compile(t);
        }
        let errors = gen.take_errors();
        assert_eq!(errors.len(), 1);
        match &errors[0] {
            CommandError::OverlappingWrites { buffer, overlap, .. } => {
                assert_eq!(*buffer, b);
                assert_eq!(overlap.area(), 64);
            }
        }
    }

    #[test]
    fn single_node_never_errors_on_all_write() {
        let mut tm = TaskManager::with_horizon_step(u64::MAX);
        let n = Range::d1(64);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        tm.submit(TaskDecl::device("ok", n).write(b, RangeMapper::All));
        let tasks = tm.take_new_tasks();
        let mut gen = CdagGenerator::new(NodeId(0), 1, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            gen.compile(t);
        }
        assert!(gen.take_errors().is_empty());
    }

    #[test]
    fn horizon_commands_prune_local_graph() {
        let mut tm = TaskManager::with_horizon_step(2);
        let n = Range::d1(64);
        let b = tm.create_buffer::<f64>("B", n, true).id();
        for _ in 0..20 {
            tm.submit(TaskDecl::device("w", n).read_write(b, RangeMapper::OneToOne));
        }
        let tasks = tm.take_new_tasks();
        let mut gen = CdagGenerator::new(NodeId(0), 1, SplitHint::D1, tm.buffers().clone());
        for t in &tasks {
            gen.compile(t);
        }
        assert!(gen.dag().len() < 15, "live={}", gen.dag().len());
        assert!(gen.dag().check_acyclic());
    }

    #[test]
    fn nodeset_basics() {
        let s = NodeSet::all(4);
        assert!(s.contains(NodeId(3)));
        assert!(!s.contains(NodeId(4)));
        assert_eq!(NodeSet::single(NodeId(2)).iter().collect::<Vec<_>>(), vec![NodeId(2)]);
        assert_eq!(NodeSet::EMPTY.insert(NodeId(1)).insert(NodeId(1)), NodeSet::single(NodeId(1)));
        assert_eq!(NodeSet::all(64).0, u64::MAX);
    }
}
