#!/usr/bin/env python3
"""Self-test for bench_gate.py: runs the gate against known-pass /
known-fail / must-skip fixture documents and checks the exit codes.

CI runs this before the real gate so a regression in the gate's own logic
(skip conditions, row normalization, threshold math) cannot silently turn
the bench gate into a no-op.

    python3 scripts/test_bench_gate.py
"""

import json
import os
import subprocess
import sys
import tempfile

GATE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_gate.py")


def run_gate(baseline, fresh, extra=()):
    with tempfile.TemporaryDirectory() as d:
        bp = os.path.join(d, "baseline.json")
        fp = os.path.join(d, "fresh.json")
        with open(bp, "w") as f:
            json.dump(baseline, f)
        with open(fp, "w") as f:
            json.dump(fresh, f)
        r = subprocess.run(
            [sys.executable, GATE, bp, fp, *extra],
            capture_output=True,
            text=True,
        )
        return r.returncode, r.stdout + r.stderr


def doc(rev, quick, components=None, rows=None):
    d = {"bench": "x", "schema": 1, "git_rev": rev, "quick": quick}
    if components is not None:
        d["components"] = components
    if rows is not None:
        d["rows"] = rows
    return d


def check(name, got, want, output):
    if got != want:
        print(f"FAIL {name}: exit {got}, wanted {want}\n{output}")
        return False
    print(f"ok   {name}")
    return True


def main():
    comp = lambda n, t: {"name": n, "ops_per_s": t}
    cases = [
        # (name, baseline, fresh, extra args, expected exit)
        (
            "unmeasured placeholder skips",
            doc("unmeasured", False, components=[]),
            doc("abc", True, components=[comp("a", 1.0)]),
            (),
            0,
        ),
        (
            "within threshold passes",
            doc("abc", True, components=[comp("a", 1000), comp("b", 500)]),
            doc("def", True, components=[comp("a", 800), comp("b", 400)]),
            (),
            0,
        ),
        (
            ">25% drop fails",
            doc("abc", True, components=[comp("a", 1000)]),
            doc("def", True, components=[comp("a", 700)]),
            (),
            1,
        ),
        (
            "missing component fails",
            doc("abc", True, components=[comp("a", 1000), comp("gone", 10)]),
            doc("def", True, components=[comp("a", 1000)]),
            (),
            1,
        ),
        (
            "extra fresh component tolerated",
            doc("abc", True, components=[comp("a", 1000)]),
            doc("def", True, components=[comp("a", 1000), comp("new", 1)]),
            (),
            0,
        ),
        (
            "quick/full mismatch skips",
            doc("abc", False, components=[comp("a", 1000)]),
            doc("def", True, components=[comp("a", 1)]),
            (),
            0,
        ),
        (
            "custom threshold",
            doc("abc", True, components=[comp("a", 1000)]),
            doc("def", True, components=[comp("a", 900)]),
            ("--threshold", "0.05"),
            1,
        ),
        (
            "ablation-suffixed rows gate independently",
            doc(
                "abc",
                True,
                rows=[
                    {"app": "wavesim", "transport": "tcp", "nodes": 2, "cells_per_s": 100.0},
                    {"app": "wavesim-staged", "transport": "tcp", "nodes": 2, "cells_per_s": 80.0},
                    {"app": "nbody-p2p-staged", "transport": "channel", "nodes": 2, "cells_per_s": 50.0},
                ],
            ),
            doc(
                "def",
                True,
                rows=[
                    {"app": "wavesim", "transport": "tcp", "nodes": 2, "cells_per_s": 95.0},
                    # The staged ablation row regressed >25%: must fail even
                    # though the direct row is healthy.
                    {"app": "wavesim-staged", "transport": "tcp", "nodes": 2, "cells_per_s": 40.0},
                    {"app": "nbody-p2p-staged", "transport": "channel", "nodes": 2, "cells_per_s": 50.0},
                ],
            ),
            (),
            1,
        ),
        (
            "ablation-suffixed rows all healthy pass",
            doc(
                "abc",
                True,
                rows=[
                    {"app": "wavesim", "transport": "tcp", "nodes": 2, "cells_per_s": 100.0},
                    {"app": "wavesim-staged", "transport": "tcp", "nodes": 2, "cells_per_s": 80.0},
                ],
            ),
            doc(
                "def",
                True,
                rows=[
                    {"app": "wavesim", "transport": "tcp", "nodes": 2, "cells_per_s": 110.0},
                    {"app": "wavesim-staged", "transport": "tcp", "nodes": 2, "cells_per_s": 78.0},
                ],
            ),
            (),
            0,
        ),
        (
            "fault-ablation rows gate independently",
            doc(
                "abc",
                True,
                rows=[
                    {"app": "wavesim", "transport": "tcp", "nodes": 2, "fault": False, "cells_per_s": 100.0},
                    {"app": "wavesim-faulty", "transport": "tcp", "nodes": 2, "fault": True, "cells_per_s": 70.0},
                ],
            ),
            doc(
                "def",
                True,
                rows=[
                    {"app": "wavesim", "transport": "tcp", "nodes": 2, "fault": False, "cells_per_s": 100.0},
                    # The recovery layer got >25% slower under injected
                    # faults: must fail even though the clean row is fine.
                    {"app": "wavesim-faulty", "transport": "tcp", "nodes": 2, "fault": True, "cells_per_s": 40.0},
                ],
            ),
            (),
            1,
        ),
        (
            "fault-ablation rows healthy pass",
            doc(
                "abc",
                True,
                rows=[
                    {"app": "wavesim", "transport": "tcp", "nodes": 2, "fault": False, "cells_per_s": 100.0},
                    {"app": "wavesim-faulty", "transport": "tcp", "nodes": 2, "fault": True, "cells_per_s": 70.0},
                ],
            ),
            doc(
                "def",
                True,
                rows=[
                    {"app": "wavesim", "transport": "tcp", "nodes": 2, "fault": False, "cells_per_s": 98.0},
                    {"app": "wavesim-faulty", "transport": "tcp", "nodes": 2, "fault": True, "cells_per_s": 66.0},
                ],
            ),
            (),
            0,
        ),
        (
            "strong_scaling rows schema",
            doc(
                "abc",
                True,
                rows=[{"app": "nbody", "transport": "tcp", "nodes": 2, "cells_per_s": 100.0}],
            ),
            doc(
                "def",
                True,
                rows=[{"app": "nbody", "transport": "tcp", "nodes": 2, "cells_per_s": 50.0}],
            ),
            (),
            1,
        ),
        (
            "p99 latency rows: higher p99 beyond threshold fails",
            doc(
                "abc",
                True,
                rows=[
                    {"app": "multijob", "transport": "channel", "nodes": 2, "cells_per_s": 100.0},
                    {"app": "multijob-j1-wavesim", "transport": "channel", "nodes": 2, "job": 1, "fair": True, "p99_fence_ms": 10.0},
                ],
            ),
            doc(
                "def",
                True,
                rows=[
                    {"app": "multijob", "transport": "channel", "nodes": 2, "cells_per_s": 100.0},
                    # Latency is lower-better: a p99 that GREW >25% must
                    # fail even though every throughput row is healthy.
                    {"app": "multijob-j1-wavesim", "transport": "channel", "nodes": 2, "job": 1, "fair": True, "p99_fence_ms": 14.0},
                ],
            ),
            (),
            1,
        ),
        (
            "p99 latency rows: lower p99 passes",
            doc(
                "abc",
                True,
                rows=[
                    {"app": "multijob-j1-wavesim", "transport": "channel", "nodes": 2, "job": 1, "fair": True, "p99_fence_ms": 10.0},
                ],
            ),
            doc(
                "def",
                True,
                rows=[
                    # A big latency IMPROVEMENT must not trip the
                    # throughput-style "dropped below (1-threshold)x" check.
                    {"app": "multijob-j1-wavesim", "transport": "channel", "nodes": 2, "job": 1, "fair": True, "p99_fence_ms": 2.0},
                ],
            ),
            (),
            0,
        ),
        (
            "p99 latency rows: missing from fresh run fails",
            doc(
                "abc",
                True,
                rows=[
                    {"app": "multijob-fifo-j0-nbody", "transport": "tcp", "nodes": 2, "job": 0, "fair": False, "p99_fence_ms": 10.0},
                ],
            ),
            doc("def", True, rows=[]),
            (),
            1,
        ),
        (
            "empty measured baseline skips",
            doc("abc", True, components=[]),
            doc("def", True, components=[comp("a", 1)]),
            (),
            0,
        ),
    ]
    ok = True
    for name, baseline, fresh, extra, want in cases:
        got, output = run_gate(baseline, fresh, extra)
        ok &= check(name, got, want, output)
        # Every skip must carry a GitHub annotation so the disarmed gate is
        # visible on the Actions summary instead of passing silently.
        if name.endswith("skips"):
            if "::notice" not in output:
                print(f"FAIL {name}: skip output lacks a ::notice annotation\n{output}")
                ok = False
            else:
                print(f"ok   {name} (annotated)")
    if not ok:
        return 1
    print("bench_gate self-test: all cases passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
