#!/usr/bin/env python3
"""Bench regression gate: compare a fresh bench JSON against the committed
baseline and fail on a significant throughput drop.

Usage:
    bench_gate.py BASELINE.json FRESH.json [--threshold 0.25]

Semantics:
  - Baselines whose "git_rev" is "unmeasured" are schema placeholders (the
    repo has never been benchmarked on a real machine): the gate SKIPS and
    exits 0, printing why.
  - Otherwise every component present in the baseline must reach at least
    (1 - threshold) x its baseline "ops_per_s" in the fresh run. A component
    missing from the fresh run is a failure (a silently-dropped benchmark
    must not pass the gate); components only present in the fresh run are
    reported but do not fail.
  - Works on any schema that stores [{"name"/"app"..., "ops_per_s"/"cells_per_s"}]
    rows under "components" or "rows" (micro_scheduler and strong_scaling).
    strong_scaling keys are "app/transport/Nn"; ablation rows suffix the app
    name ("nbody-p2p" = collectives off, "wavesim-staged"/"nbody-p2p-staged"
    = direct device transfers off, "wavesim-faulty" = TCP rows under a
    seeded fault plan pricing the CRC/retransmit recovery layer,
    "multijob"/"multijob-fifo" = N concurrent tenant jobs with fair-share
    dispatch on/off), so every lowering is gated separately. Extra row
    fields ("fault" etc.) are ignored by the key — only app/transport/nodes
    identify a row.
  - Rows carrying "p99_fence_ms" (the multi-tenant per-job fence-latency
    rows, keyed "app/transport/Nn/p99_ms") are latency metrics: LOWER is
    better, so the gate fails when the fresh p99 exceeds baseline x
    (1 + threshold). A row may contribute both a throughput and a latency
    key; each is gated independently.

Exit codes: 0 ok/skip, 1 regression, 2 usage or malformed input.
"""

import json
import sys


def skip(reason, detail):
    """Print a skip verdict plus a GitHub Actions annotation.

    A skipped gate exits 0, which renders as a green check — the `::notice`
    workflow command makes the skip visible on the run summary page instead
    of silently passing. Outside Actions the extra line is inert output.
    """
    print(f"bench_gate: SKIP - {detail}")
    print(f"::notice title=bench gate skipped::{reason} - the bench regression gate is NOT armed.")


def rows(doc):
    """Normalize a bench document to {key: (value, higher_is_better)}."""
    out = {}
    for row in doc.get("components", []) + doc.get("rows", []):
        if "name" in row:
            key = row["name"]
        else:
            key = "{}/{}/{}n".format(
                row.get("app", "?"), row.get("transport", "?"), row.get("nodes", "?")
            )
        thr = row.get("ops_per_s", row.get("cells_per_s"))
        if thr is not None:
            out[key] = (float(thr), True)
        p99 = row.get("p99_fence_ms")
        if p99 is not None:
            out[key + "/p99_ms"] = (float(p99), False)
    return out


def fmt(v):
    """Human-format a metric: integers for big throughputs, 3 decimals for
    small latency values."""
    return f"{v:.0f}" if v >= 100 else f"{v:.3f}"


def main(argv):
    args = []
    threshold = 0.25
    it = iter(argv[1:])
    for a in it:
        if a.startswith("--threshold"):
            threshold = float(a.split("=", 1)[1]) if "=" in a else float(next(it))
        elif a.startswith("--"):
            print(f"bench_gate: unknown flag {a}", file=sys.stderr)
            return 2
        else:
            args.append(a)
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline_path, fresh_path = args

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_gate: cannot read inputs: {e}", file=sys.stderr)
        return 2

    if baseline.get("git_rev") == "unmeasured":
        skip(
            "baseline is the 'unmeasured' placeholder",
            "committed baseline is the 'unmeasured' schema "
            "placeholder; nothing to compare against yet. To arm the gate, "
            "capture a QUICK-mode baseline (CI compares quick runs): "
            "BENCH_QUICK=1 BENCH_SCHEDULER_JSON=<repo>/BENCH_scheduler.json "
            "cargo bench --bench micro_scheduler, then commit the file.",
        )
        return 0
    if baseline.get("quick") != fresh.get("quick"):
        skip(
            "quick/full run mismatch",
            "baseline quick={} vs fresh quick={}; "
            "quick and full runs are not comparable. CI runs quick mode, so "
            "the committed baseline must be captured with BENCH_QUICK=1 for "
            "the gate to arm.".format(baseline.get("quick"), fresh.get("quick")),
        )
        return 0

    base_rows = rows(baseline)
    fresh_rows = rows(fresh)
    if not base_rows:
        skip("baseline has no measured rows", "baseline has no measured rows.")
        return 0

    failures = []
    print(
        f"bench_gate: comparing {len(base_rows)} baseline rows "
        f"(threshold: {threshold:.0%} drop) "
        f"[baseline {baseline.get('git_rev')} vs fresh {fresh.get('git_rev')}]"
    )
    for key, (base_val, higher_better) in sorted(base_rows.items()):
        entry = fresh_rows.get(key)
        if entry is None:
            failures.append(f"{key}: missing from fresh run")
            continue
        got = entry[0]
        if higher_better:
            ratio = got / base_val if base_val > 0 else float("inf")
            ok = ratio >= 1.0 - threshold
            unit = "ops/s"
        else:
            # Latency: lower is better; a fresh p99 above baseline x
            # (1 + threshold) is the regression.
            ratio = got / base_val if base_val > 0 else float("inf")
            ok = got <= base_val * (1.0 + threshold)
            unit = "ms p99"
        status = "OK " if ok else "FAIL"
        print(f"  {status} {key}: {fmt(base_val)} -> {fmt(got)} ({ratio:.2f}x)")
        if not ok:
            failures.append(f"{key}: {fmt(base_val)} -> {fmt(got)} {unit} ({ratio:.2f}x)")
    for key in sorted(set(fresh_rows) - set(base_rows)):
        print(f"  NEW {key}: {fmt(fresh_rows[key][0])} (no baseline)")

    if failures:
        print("\nbench_gate: REGRESSION", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        return 1
    print("bench_gate: all components within threshold.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
