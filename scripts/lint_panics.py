#!/usr/bin/env python3
"""Panic-path lint for the runtime library.

Scheduler, executor and comm threads must not die on unstructured panics:
§4.4 of the paper routes every user-facing failure through the error
stream, and a panicking runtime thread turns an attributable error into a
hang or an abort. This lint enforces the crate policy:

  - `.unwrap()` is banned outside test code, full stop (the compiler also
    warns via `clippy::unwrap_used`; this script is the no-toolchain
    backstop and covers the bin crate too).
  - `.expect(...)`, `panic!`, `unreachable!`, `todo!`, `unimplemented!`
    are budgeted per file by the allowlist below. Every budget carries a
    one-line justification; exceeding it fails CI, so a new panic path
    needs a conscious allowlist edit in the same diff.

Test code is exempt: everything from the first `#[cfg(test)]` line to end
of file (the repo convention puts the test module last) and separate test
targets under `rust/tests/`, `rust/benches/` are not scanned. Comment and
doc-comment lines are ignored.

Usage:
    lint_panics.py [--root rust/src]
    lint_panics.py --self-test

Exit codes: 0 ok, 1 policy violation, 2 usage error.
"""

import os
import re
import sys

# file (relative to the scan root) -> (budget, justification).
# A budget covers expect/panic/unreachable/todo/unimplemented combined;
# unwrap is never budgeted. Keep budgets tight: lowering one when sites are
# removed is encouraged (the lint prints a ratchet hint), raising one needs
# a justification that names why the new site cannot be an error path.
ALLOWLIST = {
    "apps/nbody.rs": (4, "example driver: submit/fence failures abort the demo by design"),
    "apps/rsim.rs": (3, "example driver: submit/fence failures abort the demo by design"),
    "apps/wavesim.rs": (2, "example driver: submit/fence failures abort the demo by design"),
    "buffer/mod.rs": (1, "dtype registered at create_buffer; mismatch is a typed-handle forgery"),
    "comm/channel.rs": (2, "lock poisoning + endpoint taken twice are wiring bugs at startup"),
    "comm/tcp.rs": (2, "lock poisoning propagates a prior panic; not a data-path failure"),
    "comm/wire.rs": (2, "fixed-size header slices; lengths are compile-time constants"),
    "command/mod.rs": (6, "buffer states inserted at creation; absence is a CDAG-internal bug"),
    "dag/mod.rs": (1, "node id handed out by this Dag; absence is memory corruption"),
    "driver/mod.rs": (9, "startup wiring (thread spawn, endpoint take) + lock poisoning"),
    "dtype/mod.rs": (2, "layout sizes are compile-time constants"),
    "executor/arbitration.rs": (1, "arbiter invariant: active receive tracked until retired"),
    "executor/arena.rs": (2, "allocation liveness is IDAG-ordered; a dead id is a scheduler bug"),
    "executor/events.rs": (4, "event hub lock poisoning propagates a prior panic"),
    "executor/fair.rs": (2, "ready-set pick() returns only nonempty queues"),
    "executor/lanes.rs": (2, "lane thread spawn at startup; send to own lane cannot disconnect"),
    "executor/mod.rs": (4, "registry lock poisoning + executor thread spawn at startup"),
    "executor/ooo.rs": (1, "engine invariant: retiring instruction was dispatched"),
    "grid/region_map.rs": (7, "iterator invariants proven by adjacent len checks (hot path)"),
    "instruction/generator.rs": (12, "IDAG invariants: buffer states and backings tracked since creation"),
    "launch/mod.rs": (9, "launcher process: spawn/lock failures abort the whole launch by design"),
    "main.rs": (9, "CLI binary: argument/setup failures abort before any cluster state exists"),
    "runtime/mod.rs": (2, "pjrt-gated; 4-byte chunks are exact by construction"),
    "scheduler/thread.rs": (1, "scheduler thread spawn at startup"),
    "sim/mod.rs": (4, "simulator-internal maps keyed by emitted instructions; times never NaN"),
    "task/manager.rs": (2, "TDAG invariant: epoch ids and buffer states tracked since creation"),
    "trace/mod.rs": (2, "trace sink lock poisoning propagates a prior panic"),
}

UNWRAP = re.compile(r"\.unwrap\(\)")
BUDGETED = re.compile(r"\.expect\(|panic!\(|unreachable!\(|todo!\(|unimplemented!\(")


def scan_file(path, text):
    """Return (unwrap_sites, budgeted_sites) as lists of (lineno, line)."""
    unwraps, budgeted = [], []
    in_test = False
    for i, line in enumerate(text.split("\n"), 1):
        if "#[cfg(test)]" in line:
            in_test = True
        if in_test:
            continue
        stripped = line.strip()
        if stripped.startswith(("//", "///", "//!")):
            continue
        if UNWRAP.search(line):
            unwraps.append((i, stripped))
        for _ in BUDGETED.findall(line):
            budgeted.append((i, stripped))
    return unwraps, budgeted


def lint(root):
    failures = []
    hints = []
    seen = set()
    for dirpath, _, files in os.walk(root):
        for f in sorted(files):
            if not f.endswith(".rs"):
                continue
            path = os.path.join(dirpath, f)
            rel = os.path.relpath(path, root)
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
            unwraps, budgeted = scan_file(path, text)
            seen.add(rel)
            for lineno, line in unwraps:
                failures.append(
                    f"{path}:{lineno}: banned .unwrap() outside tests "
                    f"(use .expect(\"why this cannot fail\") or an error path): {line}"
                )
            budget, _ = ALLOWLIST.get(rel, (0, None))
            if len(budgeted) > budget:
                failures.append(
                    f"{path}: {len(budgeted)} panic-capable site(s), allowlist budget is "
                    f"{budget} — convert the new site(s) to reported errors or raise the "
                    f"budget in scripts/lint_panics.py with a justification:"
                )
                for lineno, line in budgeted:
                    failures.append(f"  {path}:{lineno}: {line}")
            elif len(budgeted) < budget:
                hints.append(
                    f"{rel}: budget {budget} but only {len(budgeted)} site(s) — "
                    f"ratchet the allowlist down"
                )
    for rel in sorted(set(ALLOWLIST) - seen):
        failures.append(f"allowlist entry for missing file: {rel}")
    return failures, hints


def self_test():
    import tempfile

    cases = [
        # (name, source, expect_unwraps, expect_budgeted)
        ("plain unwrap is caught", "fn f() { x.unwrap(); }", 1, 0),
        ("test-module unwrap is exempt", "fn f() {}\n#[cfg(test)]\nmod t { fn g() { x.unwrap(); } }", 0, 0),
        ("doc-comment unwrap is ignored", "/// call `.unwrap()` here\nfn f() {}", 0, 0),
        ("inner-doc unwrap is ignored", "//! `.unwrap()` in module docs\nfn f() {}", 0, 0),
        ("expect is budgeted", 'fn f() { x.expect("y"); }', 0, 1),
        ("panic is budgeted", 'fn f() { panic!("bad"); }', 0, 1),
        ("unreachable is budgeted", "fn f() { unreachable!() }", 0, 1),
        ("two on one line both count", 'fn f() { a.expect("x"); panic!("y"); }', 0, 2),
        ("comment expect is ignored", '// a.expect("x")\nfn f() {}', 0, 0),
    ]
    for name, src, want_u, want_b in cases:
        unwraps, budgeted = scan_file("<fixture>", src)
        assert len(unwraps) == want_u, f"self-test failed: {name}: unwraps={unwraps}"
        assert len(budgeted) == want_b, f"self-test failed: {name}: budgeted={budgeted}"

    # End-to-end: a temp tree with one over-budget file fails, empty passes.
    with tempfile.TemporaryDirectory() as d:
        with open(os.path.join(d, "bad.rs"), "w", encoding="utf-8") as fh:
            fh.write("fn f() { x.unwrap(); }\n")
        failures, _ = lint(d)
        assert any("banned .unwrap()" in f for f in failures), "self-test: lint missed unwrap"
        # allowlist entries all refer to files outside this temp tree
        assert any("allowlist entry for missing file" in f for f in failures)
    print("lint_panics.py: self-test OK")
    return 0


def main(argv):
    if "--self-test" in argv:
        return self_test()
    root = "rust/src"
    if "--root" in argv:
        root = argv[argv.index("--root") + 1]
    if not os.path.isdir(root):
        print(f"lint_panics.py: no such directory: {root}", file=sys.stderr)
        return 2
    failures, hints = lint(root)
    for h in hints:
        print(f"note: {h}")
    if failures:
        for f in failures:
            print(f, file=sys.stderr)
        print(f"\nlint_panics.py: {len(failures)} violation(s)", file=sys.stderr)
        return 1
    print("lint_panics.py: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
