#!/usr/bin/env python3
"""Validate a Chrome-tracing JSON document emitted by `--trace`.

CI runs this against a real trace from a live multi-node run (and the
`--self-test` fixtures before that), so a schema drift in the Rust exporter
fails the build instead of silently producing files chrome://tracing
rejects.

Usage:
    check_trace.py TRACE.json [TRACE2.json ...]
    check_trace.py --self-test

Checks per document:
  - parses as JSON with a non-empty "traceEvents" list,
  - every node (pid) has a process_name and every track a thread_name,
  - every event has ph/pid/tid; ts >= 0 and dur >= 0 where present,
  - non-metadata events are monotonic in file order (the exporter sorts),
  - per pid, every retired instruction id was previously issued,
  - per pid, "compiled" events carry their dependency edges as a JSON list
    and the executor's completion order respects them: an instruction must
    never retire before a static dependency that also retires in the trace
    (a completion-order inversion means the executor violated the IDAG).

Fault-injection runs additionally emit "fault" (args: from/what/fatal),
"reconnect" and "retransmit" (args: peer) instants on the comm-in track;
the checks above are event-name-agnostic, so these validate like any other
instant — the self-test fixture includes them to pin the schema.

Exit codes: 0 ok, 1 schema violation, 2 usage or unreadable input.
"""

import json
import sys


def check_doc(doc, path):
    errors = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: traceEvents missing or empty"]

    named_pids = set()
    named_tids = set()
    seen_pids = set()
    seen_tids = set()
    issued = {}  # pid -> set of instruction ids
    retired = {}
    compiled = {}  # pid -> {instr: [dep ids]}
    retire_pos = {}  # pid -> {instr: file-order index of its retire event}
    last_ts = None
    for i, ev in enumerate(events):
        where = f"{path}: event {i}"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        pid = ev.get("pid")
        tid = ev.get("tid")
        if ph is None or pid is None or tid is None:
            errors.append(f"{where}: missing ph/pid/tid: {ev}")
            continue
        if ph == "M":
            if ev.get("name") == "process_name":
                named_pids.add(pid)
            elif ev.get("name") == "thread_name":
                named_tids.add((pid, tid))
            continue
        seen_pids.add(pid)
        seen_tids.add((pid, tid))
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: bad ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(f"{where}: ts {ts} < previous {last_ts} (file must be sorted)")
        last_ts = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: span with bad dur {dur!r}")
        elif ph == "i":
            if ev.get("s") not in ("t", "p", "g"):
                errors.append(f"{where}: instant without a valid scope: {ev}")
        name = ev.get("name")
        instr = (ev.get("args") or {}).get("instr")
        if name == "issue" and instr is not None:
            issued.setdefault(pid, set()).add(instr)
        if name == "retire" and instr is not None:
            retired.setdefault(pid, set()).add(instr)
            retire_pos.setdefault(pid, {}).setdefault(instr, i)
        if name == "compiled" and instr is not None:
            deps = (ev.get("args") or {}).get("deps")
            if not isinstance(deps, list) or not all(isinstance(d, int) for d in deps):
                errors.append(f"{where}: compiled event without a deps list: {ev}")
            else:
                compiled.setdefault(pid, {})[instr] = deps

    for pid in sorted(seen_pids):
        if pid not in named_pids:
            errors.append(f"{path}: pid {pid} has events but no process_name metadata")
    for pid, tid in sorted(seen_tids):
        if (pid, tid) not in named_tids:
            errors.append(f"{path}: tid {pid}/{tid} has events but no thread_name metadata")
    for pid, rets in sorted(retired.items()):
        ghosts = rets - issued.get(pid, set())
        if ghosts:
            errors.append(
                f"{path}: pid {pid} retired {len(ghosts)} instruction(s) never issued, "
                f"e.g. {sorted(ghosts)[:5]}"
            )
    # Completion order must respect the static dependency edges: for every
    # compiled edge dep -> instr where both retire in the trace, the dep's
    # retire must come first. (Edges to instructions that never retire in
    # the window — e.g. pruned before tracing started — are skipped.)
    for pid, instrs in sorted(compiled.items()):
        pos = retire_pos.get(pid, {})
        for instr, deps in sorted(instrs.items()):
            if instr not in pos:
                continue
            for dep in deps:
                if dep in pos and pos[dep] > pos[instr]:
                    errors.append(
                        f"{path}: pid {pid}: completion order inverts a static "
                        f"dependency: instruction {instr} retired before its "
                        f"dependency {dep}"
                    )
    return errors


def check_file(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_trace: cannot read {path}: {e}", file=sys.stderr)
        return 2
    errors = check_doc(doc, path)
    if errors:
        print(f"check_trace: {path}: SCHEMA VIOLATIONS", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    n = sum(1 for ev in doc["traceEvents"] if ev.get("ph") != "M")
    print(f"check_trace: {path}: ok ({n} events)")
    return 0


def self_test():
    """Fixture documents exercising both the accept and every reject path."""
    meta = [
        {"ph": "M", "name": "process_name", "pid": 0, "tid": 0, "args": {"name": "node 0"}},
        {"ph": "M", "name": "thread_name", "pid": 0, "tid": 1, "args": {"name": "executor"}},
    ]
    good = meta + [
        {"ph": "i", "s": "t", "name": "compiled", "pid": 0, "tid": 1, "ts": 0.5,
         "args": {"instr": 7, "deps": []}},
        {"ph": "i", "s": "t", "name": "compiled", "pid": 0, "tid": 1, "ts": 0.6,
         "args": {"instr": 8, "deps": [7]}},
        {"ph": "i", "s": "t", "name": "issue", "pid": 0, "tid": 1, "ts": 1.0,
         "args": {"instr": 7}},
        {"ph": "X", "name": "device kernel", "pid": 0, "tid": 1, "ts": 2.0, "dur": 3.5,
         "args": {"instr": 7}},
        {"ph": "i", "s": "t", "name": "retire", "pid": 0, "tid": 1, "ts": 6.0,
         "args": {"instr": 7}},
        # Fault-recovery instants (comm-in track): schema-pinned here so the
        # exporter can't drift for chaos runs.
        {"ph": "i", "s": "t", "name": "fault", "pid": 0, "tid": 1, "ts": 6.5,
         "args": {"from": 1, "what": "corrupt", "fatal": False}},
        {"ph": "i", "s": "t", "name": "reconnect", "pid": 0, "tid": 1, "ts": 6.6,
         "args": {"peer": 1}},
        {"ph": "i", "s": "t", "name": "retransmit", "pid": 0, "tid": 1, "ts": 6.7,
         "args": {"peer": 1}},
        # Instruction 8 depends on 7 and retires after it: the completion
        # order respects the compiled edge.
        {"ph": "i", "s": "t", "name": "issue", "pid": 0, "tid": 1, "ts": 6.8,
         "args": {"instr": 8}},
        {"ph": "i", "s": "t", "name": "retire", "pid": 0, "tid": 1, "ts": 6.9,
         "args": {"instr": 8}},
    ]
    # Same events, but instruction 8 (which depends on 7) retires first:
    # a completion-order inversion the executor must never produce.
    inverted = meta + [
        {"ph": "i", "s": "t", "name": "compiled", "pid": 0, "tid": 1, "ts": 0.5,
         "args": {"instr": 7, "deps": []}},
        {"ph": "i", "s": "t", "name": "compiled", "pid": 0, "tid": 1, "ts": 0.6,
         "args": {"instr": 8, "deps": [7]}},
        {"ph": "i", "s": "t", "name": "issue", "pid": 0, "tid": 1, "ts": 1.0,
         "args": {"instr": 7}},
        {"ph": "i", "s": "t", "name": "issue", "pid": 0, "tid": 1, "ts": 1.1,
         "args": {"instr": 8}},
        {"ph": "i", "s": "t", "name": "retire", "pid": 0, "tid": 1, "ts": 2.0,
         "args": {"instr": 8}},
        {"ph": "i", "s": "t", "name": "retire", "pid": 0, "tid": 1, "ts": 3.0,
         "args": {"instr": 7}},
    ]
    cases = [
        ("valid document accepted", {"traceEvents": good}, 0),
        ("empty traceEvents rejected", {"traceEvents": []}, 1),
        ("negative dur rejected",
         {"traceEvents": meta + [{"ph": "X", "name": "k", "pid": 0, "tid": 1, "ts": 1.0,
                                  "dur": -1.0}]}, 1),
        ("unsorted ts rejected",
         {"traceEvents": meta + [
             {"ph": "i", "s": "t", "name": "a", "pid": 0, "tid": 1, "ts": 5.0},
             {"ph": "i", "s": "t", "name": "b", "pid": 0, "tid": 1, "ts": 1.0}]}, 1),
        ("unnamed pid rejected",
         {"traceEvents": [{"ph": "i", "s": "t", "name": "a", "pid": 9, "tid": 0, "ts": 0.0}]}, 1),
        ("retire without issue rejected",
         {"traceEvents": meta + [{"ph": "i", "s": "t", "name": "retire", "pid": 0, "tid": 1,
                                  "ts": 1.0, "args": {"instr": 3}}]}, 1),
        ("completion-order inversion rejected", {"traceEvents": inverted}, 1),
        ("compiled without a deps list rejected",
         {"traceEvents": meta + [{"ph": "i", "s": "t", "name": "compiled", "pid": 0, "tid": 1,
                                  "ts": 1.0, "args": {"instr": 3, "deps": 2}}]}, 1),
    ]
    ok = True
    for name, doc, want in cases:
        got = 1 if check_doc(doc, "<fixture>") else 0
        status = "ok  " if got == want else "FAIL"
        ok &= got == want
        print(f"{status} {name}")
    if not ok:
        return 1
    print("check_trace self-test: all cases passed.")
    return 0


def main(argv):
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    if argv[1] == "--self-test":
        return self_test()
    rc = 0
    for path in argv[1:]:
        rc = max(rc, check_file(path))
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
