#!/usr/bin/env bash
# Capture the quick-mode bench baselines the CI gate compares against.
#
# The committed BENCH_scheduler.json / BENCH_strong_scaling.json start as
# "git_rev": "unmeasured" schema placeholders, which makes
# scripts/bench_gate.py skip. Running this script on a real machine (or via
# the ci.yml `bench-baseline` workflow_dispatch job) overwrites them with
# measured quick-mode numbers — committing the result arms the gate.
#
# Quick mode is mandatory: CI's smoke jobs run BENCH_QUICK=1, and the gate
# refuses to compare quick runs against a full-mode baseline.
#
# The strong_scaling baseline includes the ablation rows ("nbody-p2p" =
# collectives off, "wavesim-staged"/"nbody-p2p-staged" = direct device
# transfers off); re-capture after adding/renaming ablation variants so the
# gate's per-row keys stay in sync with the bench output.
set -euo pipefail

repo="$(cd "$(dirname "$0")/.." && pwd)"
cd "$repo/rust"

echo "== capturing quick-mode micro_scheduler baseline =="
BENCH_QUICK=1 BENCH_SCHEDULER_JSON="$repo/BENCH_scheduler.json" \
    cargo bench --bench micro_scheduler

echo "== capturing quick-mode strong_scaling baseline =="
BENCH_QUICK=1 BENCH_STRONG_SCALING_JSON="$repo/BENCH_strong_scaling.json" \
    cargo bench --bench strong_scaling

echo
echo "Baselines written to:"
echo "  $repo/BENCH_scheduler.json"
echo "  $repo/BENCH_strong_scaling.json"
echo "Commit both files to arm scripts/bench_gate.py in CI."
