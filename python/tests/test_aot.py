"""AOT path: lowering emits PJRT-parsable HLO text and a sound manifest."""

import jax.numpy as jnp

from compile import aot, model


def test_hlo_text_has_entry_computation():
    text = aot.to_hlo_text(
        model.nbody_update,
        jnp.zeros((8, 3), jnp.float32),
        jnp.zeros((8, 3), jnp.float32),
    )
    assert "ENTRY" in text
    assert "f32[8,3]" in text


def test_kernel_table_covers_all_apps():
    table = aot.kernel_table(64, 16, 8, 16, 8, 16)
    assert set(table) == {"nbody_timestep", "nbody_update", "wavesim_step", "rsim_row"}


def test_manifest_spec_format():
    spec = aot._spec((4, 3), jnp.float32)
    assert aot._fmt(spec) == "f32:4x3"
    scalar = aot._spec((1,), jnp.int32)
    assert aot._fmt(scalar) == "i32:1"


def test_pallas_kernels_survive_jit_lowering():
    # The pallas_call (interpret=True) must lower into plain HLO: no
    # custom-call to Mosaic may remain.
    text = aot.to_hlo_text(
        model.wavesim_step_model,
        jnp.zeros((10, 16), jnp.float32),
        jnp.zeros((10, 16), jnp.float32),
    )
    assert "mosaic" not in text.lower()
