"""L2 correctness: model entry points vs references + shape contracts."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

TOL = dict(rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), off_idx=st.integers(0, 3))
def test_nbody_timestep_matches_ref(seed, off_idx):
    rng = np.random.default_rng(seed)
    n, c = 64, 16
    p = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    offset = off_idx * c
    v = jnp.asarray(rng.standard_normal((c, 3)), jnp.float32)
    (got,) = model.nbody_timestep(p, v, jnp.array([offset], jnp.int32))
    want = ref.nbody_timestep_ref(p, v, offset)
    np.testing.assert_allclose(got, want, **TOL)


def test_nbody_update_is_euler_step():
    v = jnp.ones((8, 3), jnp.float32)
    p = jnp.zeros((8, 3), jnp.float32)
    (got,) = model.nbody_update(v, p)
    np.testing.assert_allclose(got, ref.DT * jnp.ones((8, 3)), rtol=1e-6)


def test_model_outputs_are_tuples():
    # The AOT path lowers with return_tuple=True; entry points must return
    # tuples so input/output marshalling in Rust stays positional.
    p = jnp.zeros((16, 3), jnp.float32)
    v = jnp.zeros((4, 3), jnp.float32)
    out = model.nbody_timestep(p, v, jnp.array([0], jnp.int32))
    assert isinstance(out, tuple) and len(out) == 1


def test_wavesim_energy_dissipates_from_impulse():
    # A point impulse spreads; total |u| stays bounded over a few steps.
    rows, cols = 16, 16
    u0 = jnp.zeros((rows, cols), jnp.float32).at[8, 8].set(1.0)
    prev, curr = u0, u0
    for _ in range(5):
        win_p = jnp.pad(prev, ((1, 1), (0, 0)))
        win_c = jnp.pad(curr, ((1, 1), (0, 0)))
        (nxt,) = model.wavesim_step_model(win_p, win_c)
        prev, curr = curr, nxt
    assert bool(jnp.all(jnp.isfinite(curr)))
    assert float(jnp.max(jnp.abs(curr))) < 10.0


def test_rsim_rows_grow_history():
    t_max, w = 8, 16
    rng = np.random.default_rng(1)
    vis = jnp.asarray(np.abs(rng.standard_normal((w, w))) * 0.1, jnp.float32)
    buf = jnp.zeros((t_max, w), jnp.float32).at[0].set(1.0)
    for t in range(1, t_max):
        (row,) = model.rsim_row_model(buf, vis, jnp.array([t], jnp.int32))
        buf = buf.at[t].set(row)
    assert bool(jnp.all(jnp.isfinite(buf)))
    # Every appended row reflects the accumulated history.
    want1 = ref.rsim_row_ref(
        jnp.zeros((t_max, w), jnp.float32).at[0].set(1.0), vis, jnp.int32(1)
    )
    np.testing.assert_allclose(buf[1], want1, **TOL)
