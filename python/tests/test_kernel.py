"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and data; assert_allclose against ref.py. This is
the core correctness signal for the compute layer — the Rust runtime
executes exactly these lowered kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gravity_forces, ref, rsim_row, wavesim_step

TOL = dict(rtol=1e-4, atol=1e-5)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 96),
    c_frac=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([8, 16, 32]),
)
def test_gravity_matches_ref(n, c_frac, seed, tile):
    rng = np.random.default_rng(seed)
    c = max(1, n // c_frac)
    p_all = rand(rng, n, 3)
    p_chunk = p_all[:c]
    got = gravity_forces(p_all, p_chunk, tile_i=tile)
    want = ref.nbody_forces_ref(p_all, p_chunk)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 24),
    cols=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_stencil_matches_ref(rows, cols, seed):
    rng = np.random.default_rng(seed)
    u_prev = rand(rng, rows + 2, cols)
    u_curr = rand(rng, rows + 2, cols)
    got = wavesim_step(u_prev, u_curr)
    want = ref.wavesim_step_ref(u_prev, u_curr)
    assert got.shape == (rows, cols)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=20, deadline=None)
@given(
    t_max=st.integers(2, 24),
    width=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([8, 16, 32]),
)
def test_radmv_matches_ref(t_max, width, seed, tile):
    rng = np.random.default_rng(seed)
    t = int(rng.integers(1, t_max))
    prev = rand(rng, t_max, width)
    vis = rand(rng, width, width)
    t_arr = jnp.array([t], jnp.int32)
    got = rsim_row(prev, vis, t_arr, tile_j=tile)
    want = ref.rsim_row_ref(prev, vis, jnp.int32(t))
    np.testing.assert_allclose(got, want, **TOL)


def test_gravity_zero_distance_softened():
    # Coincident bodies must not produce NaNs (softening).
    p = jnp.zeros((8, 3), jnp.float32)
    f = gravity_forces(p, p)
    assert bool(jnp.all(jnp.isfinite(f)))
    np.testing.assert_allclose(f, jnp.zeros_like(f), atol=1e-6)


def test_stencil_zero_field_stays_zero():
    z = jnp.zeros((10, 16), jnp.float32)
    out = wavesim_step(z, z)
    np.testing.assert_allclose(out, jnp.zeros((8, 16)), atol=0)


def test_radmv_t_zero_row_is_zero():
    prev = jnp.ones((8, 16), jnp.float32)
    vis = jnp.ones((16, 16), jnp.float32)
    out = rsim_row(prev, vis, jnp.array([0], jnp.int32))
    np.testing.assert_allclose(out, jnp.zeros(16), atol=0)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_kernels_preserve_dtype(dtype):
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.standard_normal((16, 3)), dtype)
    assert gravity_forces(p, p).dtype == dtype
