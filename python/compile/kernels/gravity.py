"""L1 Pallas kernel: softened pairwise gravity (the N-body hot spot).

TPU adaptation of the paper's CUDA-style kernel (DESIGN.md
§Hardware-Adaptation): instead of staging j-tiles of the position array in
CUDA shared memory per threadblock, the i-axis is tiled via the grid and
each program instance receives the full position array as a VMEM-resident
block (the all-gather operand the runtime materializes per device) plus its
i-tile. Force accumulation stays in registers/VMEM.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls (real-TPU lowering); interpret mode lowers to plain HLO.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS2

DEFAULT_TILE_I = 32


def _gravity_kernel(p_all_ref, p_chunk_ref, f_ref):
    p_all = p_all_ref[...]  # (N, 3) — full positions in VMEM
    p_i = p_chunk_ref[...]  # (TI, 3) — this program's i-tile
    diff = p_all[None, :, :] - p_i[:, None, :]  # (TI, N, 3)
    dist2 = jnp.sum(diff * diff, axis=-1) + EPS2
    inv_d3 = dist2 ** (-1.5)
    f_ref[...] = jnp.sum(diff * inv_d3[..., None], axis=1)


def gravity_forces(p_all, p_chunk, tile_i=DEFAULT_TILE_I):
    """Net force on each body of ``p_chunk`` from all bodies in ``p_all``.

    Tiled over the chunk axis; the tile size falls back to the whole chunk
    when it does not divide evenly.
    """
    c = p_chunk.shape[0]
    n = p_all.shape[0]
    ti = tile_i if c % tile_i == 0 else c
    return pl.pallas_call(
        _gravity_kernel,
        grid=(c // ti,),
        in_specs=[
            pl.BlockSpec((n, 3), lambda i: (0, 0)),
            pl.BlockSpec((ti, 3), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ti, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 3), jnp.float32),
        interpret=True,
    )(p_all, p_chunk)
