"""L1 Pallas kernels (build-time only) and their pure-jnp oracles."""

from . import ref  # noqa: F401
from .gravity import gravity_forces  # noqa: F401
from .radmv import rsim_row  # noqa: F401
from .stencil5 import wavesim_step  # noqa: F401
