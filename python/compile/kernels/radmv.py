"""L1 Pallas kernel: RSim radiosity row (masked reduce + matvec).

The growing access pattern (read rows [0, t), append row t) is padded to a
fixed maximal shape so a single AOT artifact serves every time step: rows
>= t are masked out inside the kernel. The matvec against the visibility
matrix is tiled over output columns — on a real TPU each (W × TJ) tile of
``vis`` is an MXU-shaped operand staged in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import RSIM_NORM

DEFAULT_TILE_J = 32


def _radmv_kernel(prev_ref, vis_ref, t_ref, out_ref):
    prev = prev_ref[...]  # (T, W) — padded history
    vis = vis_ref[...]  # (W, TJ) — column tile of the visibility matrix
    t = t_ref[0]
    mask = (jnp.arange(prev.shape[0]) < t)[:, None]
    s = jnp.sum(prev * mask, axis=0)  # (W,) illumination so far
    scale = RSIM_NORM / jnp.maximum(t.astype(jnp.float32), 1.0)
    out_ref[...] = (s @ vis) * scale


def rsim_row(prev_rows, vis, t, tile_j=DEFAULT_TILE_J):
    """Compute radiosity row ``t`` from the (padded) history and the
    visibility matrix. ``t`` is a (1,)-shaped int32 array."""
    big_t, w = prev_rows.shape
    tj = tile_j if w % tile_j == 0 else w
    return pl.pallas_call(
        _radmv_kernel,
        grid=(w // tj,),
        in_specs=[
            pl.BlockSpec((big_t, w), lambda j: (0, 0)),
            pl.BlockSpec((w, tj), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((tj,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.float32),
        interpret=True,
    )(prev_rows, vis, t)
