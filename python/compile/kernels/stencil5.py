"""L1 Pallas kernel: five-point wave-propagation stencil (WaveSim).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the halo-exchange idiom of
CUDA threadblocks (stage tile+halo into shared memory) becomes
halo-in-block — each device receives its row window *including* the halo
rows from the runtime's coherence machinery, so the kernel itself is a
single VMEM-resident block program. Column tiling (for wide grids) would
add a second grid axis with overlapping column windows; at the shard sizes
used here one block fits comfortably in a 16 MiB VMEM budget
(18×64 f32 windows = 4.5 KiB).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import WAVE_C


def _stencil_kernel(u_prev_ref, u_curr_ref, out_ref):
    u = u_curr_ref[...]  # (R+2, C) window with halo rows
    up = u[:-2, :]
    down = u[2:, :]
    mid = u[1:-1, :]
    left = jnp.pad(mid[:, :-1], ((0, 0), (1, 0)))  # zero Dirichlet boundary
    right = jnp.pad(mid[:, 1:], ((0, 0), (0, 1)))
    lap = up + down + left + right - 4.0 * mid
    out_ref[...] = 2.0 * mid - u_prev_ref[1:-1, :] + WAVE_C * lap


def wavesim_step(u_prev_win, u_curr_win):
    """One stencil step over a haloed row window: returns the interior rows.

    Both windows have shape (rows+2, cols); edge chunks are zero-padded by
    the caller (zero boundary condition).
    """
    rp2, c = u_curr_win.shape
    rows = rp2 - 2
    return pl.pallas_call(
        _stencil_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((rp2, c), lambda i: (0, 0)),
            pl.BlockSpec((rp2, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, c), jnp.float32),
        interpret=True,
    )(u_prev_win, u_curr_win)
