"""Pure-jnp oracles for the three application kernels.

These are the correctness references the Pallas kernels (L1) are validated
against in ``python/tests/``, and double as the numerics the Rust-side
reference implementations in ``rust/src/apps`` must agree with.
"""

import jax.numpy as jnp

# Physics constants baked into the AOT artifacts (must match rust/src/apps).
DT = 1e-3  # integration time step
M = 1.0  # body mass
EPS2 = 1e-4  # gravitational softening
WAVE_C = 0.25  # wave propagation coefficient (c*dt/dx)^2
RSIM_NORM = 0.5  # radiosity reflectance normalization


def nbody_forces_ref(p_all, p_chunk):
    """Softened pairwise gravity acting on each body of ``p_chunk``.

    p_all: (N, 3) positions of all bodies.
    p_chunk: (C, 3) positions of the bodies owned by this shard.
    returns: (C, 3) net force on each chunk body.
    """
    diff = p_all[None, :, :] - p_chunk[:, None, :]  # (C, N, 3)
    dist2 = jnp.sum(diff * diff, axis=-1) + EPS2  # (C, N)
    inv_d3 = dist2 ** (-1.5)
    return jnp.sum(diff * inv_d3[..., None], axis=1)  # (C, 3)


def nbody_timestep_ref(p_all, v_chunk, offset):
    """Velocity update for the chunk starting at ``offset``: Listing 1's
    "timestep" kernel."""
    c = v_chunk.shape[0]
    p_chunk = jnp.take(p_all, offset + jnp.arange(c), axis=0)
    f = nbody_forces_ref(p_all, p_chunk)
    return v_chunk + M * f * DT


def nbody_update_ref(v_chunk, p_chunk):
    """Position update: Listing 1's "update" kernel."""
    return p_chunk + v_chunk * DT


def wavesim_step_ref(u_prev_win, u_curr_win):
    """Five-point wave-propagation stencil.

    Windows carry one halo row above and below the written chunk (edge
    chunks are zero-padded by the caller — zero Dirichlet boundary):

    u_next = 2*u - u_prev + WAVE_C * laplacian(u), evaluated on the
    interior rows of the window.
    """
    u = u_curr_win
    lap = (
        u[:-2, :]  # up
        + u[2:, :]  # down
        + jnp.pad(u[1:-1, :-1], ((0, 0), (1, 0)))  # left (zero boundary)
        + jnp.pad(u[1:-1, 1:], ((0, 0), (0, 1)))  # right
        - 4.0 * u[1:-1, :]
    )
    return 2.0 * u[1:-1, :] - u_prev_win[1:-1, :] + WAVE_C * lap


def rsim_row_ref(prev_rows, vis, t):
    """RSim radiosity row: the new row t is the reflectance-weighted
    illumination from all rows produced so far.

    prev_rows: (T, W) buffer contents; only rows [0, t) are valid.
    vis: (W, W) visibility/reflectance matrix.
    t: scalar int32 — the current time step (>= 1).
    returns: (W,) the new row.
    """
    T = prev_rows.shape[0]
    mask = (jnp.arange(T) < t)[:, None]  # (T, 1)
    s = jnp.sum(prev_rows * mask, axis=0)  # (W,)
    return (s @ vis) * (RSIM_NORM / jnp.maximum(t.astype(jnp.float32), 1.0))
