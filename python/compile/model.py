"""L2: the application compute graphs, written in JAX, calling the L1
Pallas kernels. These are the functions ``aot.py`` lowers to HLO text; one
artifact per (function, shard shape).

Conventions shared with the Rust runtime (rust/src/runtime):

- inputs are the task's consumer accessors in declaration order, followed
  by any scalar parameters (chunk offsets, time step indices);
- outputs are the producer accessors in declaration order;
- all array dtypes are f32; scalars are i32 of shape (1,).
"""

import jax
import jax.numpy as jnp

from .kernels import gravity_forces, rsim_row, wavesim_step
from .kernels.ref import DT, M


def nbody_timestep(p_all, v_chunk, offset):
    """Listing 1 "timestep": integrate pairwise gravity into velocities.

    p_all: (N, 3) all body positions (the `all` range mapper operand).
    v_chunk: (C, 3) velocities of this shard (`one_to_one`).
    offset: (1,) i32 — first body index of the shard.
    """
    c = v_chunk.shape[0]
    p_chunk = jax.lax.dynamic_slice(p_all, (offset[0], 0), (c, 3))
    f = gravity_forces(p_all, p_chunk)
    return (v_chunk + M * f * DT,)


def nbody_update(v_chunk, p_chunk):
    """Listing 1 "update": integrate velocities into positions."""
    return (p_chunk + v_chunk * DT,)


def wavesim_step_model(u_prev_win, u_curr_win):
    """WaveSim: one five-point stencil step over a haloed row window."""
    return (wavesim_step(u_prev_win, u_curr_win),)


def rsim_row_model(prev_rows, vis, t):
    """RSim: compute radiosity row ``t`` from the padded history."""
    return (rsim_row(prev_rows, vis, t),)
