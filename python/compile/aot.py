"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the published ``xla`` crate) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts land in ``artifacts/`` together with a plain-text manifest the
Rust side parses:

    <kernel-name>\t<file>\tin=f32:256x3,f32:64x3,i32:1\tout=f32:64x3

Shard shapes default to the end-to-end example's configuration (1 node x 4
devices) and can be overridden on the command line.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt(spec) -> str:
    kind = {"float32": "f32", "int32": "i32"}[str(spec.dtype)]
    dims = "x".join(str(d) for d in spec.shape)
    return f"{kind}:{dims or '1'}"


def kernel_table(n, chunk, rows, cols, t_max, width):
    """The artifact set: name -> (fn, example arg specs)."""
    f32, i32 = jnp.float32, jnp.int32
    return {
        # N-body: per-device shard of C bodies out of N.
        "nbody_timestep": (
            model.nbody_timestep,
            [_spec((n, 3), f32), _spec((chunk, 3), f32), _spec((1,), i32)],
        ),
        "nbody_update": (
            model.nbody_update,
            [_spec((chunk, 3), f32), _spec((chunk, 3), f32)],
        ),
        # WaveSim: haloed row window per device.
        "wavesim_step": (
            model.wavesim_step_model,
            [_spec((rows + 2, cols), f32), _spec((rows + 2, cols), f32)],
        ),
        # RSim: fixed-size padded history + visibility matrix.
        "rsim_row": (
            model.rsim_row_model,
            [_spec((t_max, width), f32), _spec((width, width), f32), _spec((1,), i32)],
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=256, help="N-body total bodies")
    ap.add_argument("--chunk", type=int, default=64, help="N-body shard size")
    ap.add_argument("--rows", type=int, default=16, help="WaveSim shard rows")
    ap.add_argument("--cols", type=int, default=64, help="WaveSim columns")
    ap.add_argument("--t-max", type=int, default=32, help="RSim max time steps")
    ap.add_argument("--width", type=int, default=64, help="RSim row width")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = []
    table = kernel_table(args.n, args.chunk, args.rows, args.cols, args.t_max, args.width)
    for name, (fn, specs) in table.items():
        text = to_hlo_text(fn, *specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        ins = ",".join(_fmt(s) for s in specs)
        outs_s = ",".join(_fmt(s) for s in outs)
        manifest_lines.append(f"{name}\t{fname}\tin={ins}\tout={outs_s}")
        print(f"lowered {name}: {len(text)} chars")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
